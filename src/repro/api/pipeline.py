"""Service-side asynchronous suggestion pipeline (prefetch pump + miss
coalescing) — the machinery that makes ``LocalClient.suggest`` latency
independent of model cost.

Three cooperating pieces (all operating on one ``_ExperimentState``):

* **Prefetch pump** (`SuggestionPump`): a per-experiment background thread
  that keeps a bounded queue of speculative suggestions warm.  Each queued
  suggestion was produced by a real ``ask()`` (so it carries its
  constant-liar ``__lie`` token and EI already accounts for it); the pump
  also absorbs the *deferred optimizer work* — observation folds,
  hyperparameter refits, lie retirement — that ``observe``/``release``
  only enqueue.  Cold-start XLA compile cost is moved off-path too: the
  pump prewarms the power-of-two GP shape buckets at start and again
  before the history crosses into the next bucket.

* **Miss coalescing** (`serve_misses`): concurrent ``suggest`` calls that
  find the queue dry park a `MissSlot` and race for the optimizer lock;
  the winner serves *every* parked slot with a single batched ``ask(n)``
  instead of N serialized model fits.  Losers wait on their slot's event
  — they never touch the optimizer.

* **Staleness bound**: every queued suggestion remembers the observation
  count it was computed at (``born_obs``).  Once ``staleness`` (K) new
  observations have arrived, the suggestion is *invalidated* — dropped at
  pop time (and proactively by the pump), its constant-liar lie retired —
  so a warm queue can never serve a point the model has since learned to
  avoid.  The same bound is what makes *sparse* refills safe: under
  saturation the pump refills from the optimizer's approximate
  subset-of-data posterior (``ask(n, speculative=True)``), and any
  approximation error is confined to queue entries at most K
  observations old.

* **Shared fit executor** (`FitExecutor`): hyperparameter-fit debt is
  never paid on a pump thread.  Pumps submit it to one process-wide
  priority-queue executor (miss-serving experiments first, idle
  maintenance last) whose workers run the fit compute without holding
  the experiment's optimizer lock (``Optimizer.fit_job``) — so N live
  experiments stop burning N cores on Adam loops while requests park.

Locking protocol (shared with ``repro.api.local``): ``state.opt_lock``
serializes all optimizer access (ask/tell/forget/restore) and must be
acquired *before* ``state.lock`` (cheap bookkeeping) when both are held.
``state.ops`` — the deferred tell/forget queue — is only ever popped
while holding ``opt_lock`` (see ``drain_ops``), which is what makes
create/resume's "drain then replay the log tail" sequence race-free.
"""
from __future__ import annotations

import atexit
import heapq
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

#: Largest ``ask`` the pipeline issues per optimizer-lock hold (pump
#: refill ticks and coalesced miss rounds alike).  Bounds lock latency
#: (a request arriving mid-batch waits one chunk, not one queue fill)
#: and pins the q-EI scan shapes to the power-of-two pads <= 8 — exactly
#: what ``prewarm`` compiles, so no batch size ever pays a first-touch
#: scan compile on the request path.  Coalesced misses beyond a chunk
#: stay parked and are served by the next lock winner in ~one cheap
#: recondition+scan round each (hyperfits are deferred to the pump).
#: Only a single ``suggest(count > 8)`` call exceeds the chunk.
ASK_CHUNK = 8

#: FitExecutor priorities (lower = sooner): a fit for an experiment whose
#: requests are parking on queue misses beats one whose queue merely needs
#: refilling, which beats idle maintenance debt.
PRIO_MISS, PRIO_REFILL, PRIO_IDLE = 0, 1, 2

#: Sentinel a ``BatchableFit.snapshot`` returns to mean "requeue me"
#: (the optimizer lock was contended) — distinct from None ("nothing
#: owed, drop the job").
RETRY = object()


class FitLane:
    """One experiment's snapshotted fit, ready to join a batched
    dispatch: ``spec`` is the optimizer's batchable fit descriptor
    (``Optimizer.fit_spec`` — bucket, step count, copied arrays, a
    ``runner``) and ``install`` applies the fitted hyperparameters under
    that experiment's locks.  Lanes sharing ``group_key`` fit together
    in ONE vmap'd dispatch (``gp.batched_fit``)."""

    __slots__ = ("spec", "install")

    def __init__(self, spec, install):
        self.spec = spec
        self.install = install

    @property
    def group_key(self):
        """Lanes may co-batch iff this matches.  The spec defines its own
        grouping (``FitSpec``: (runner, bucket) — step counts merge via
        the masked variable-step loop; ``AskSpec``: (runner, bucket,
        k_pad, pool shape)); legacy specs without one group on
        (runner, bucket, steps), the pre-ISSUE-10 contract."""
        key = getattr(self.spec, "group_key", None)
        if key is not None:
            return key
        return (self.spec.runner, self.spec.bucket, self.spec.steps)


class BatchableFit:
    """Marker wrapper for executor jobs that can co-batch (ISSUE 8).
    ``snapshot()`` runs on a worker thread and returns a ``FitLane``,
    ``RETRY`` (lock contention — requeue), or None (debt already paid).
    The executor gathers every queued BatchableFit whose snapshot shares
    the primary's ``group_key`` into one dispatch."""

    __slots__ = ("snapshot",)

    def __init__(self, snapshot: Callable[[], Any]):
        self.snapshot = snapshot


class BatchableAsk(BatchableFit):
    """A batchable queue-refill *ask* (ISSUE 10).  Same snapshot/gather
    machinery as ``BatchableFit`` — the spec's ``kind`` ("ask") routes
    the dispatch to the ``batched_asks``/``ask_lanes`` counters so fit
    and ask batching stay separately observable.  Miss serving never
    goes through this path: coalesced misses keep their exact inline
    ``ask`` (PRIO_MISS semantics unchanged)."""
    __slots__ = ()


class FitExecutor:
    """Process-wide executor for deferred hyperparameter fits (ISSUE 5).

    Before this existed every per-experiment pump ran its own
    ``Optimizer.maintain()`` inline: N live experiments meant N threads
    each burning a core on an Adam loop while suggest requests parked
    behind the optimizer lock.  Now pumps only recondition and pop —
    fits are *submitted* here, deduplicated per experiment, and run by a
    small shared worker pool in priority order (miss-serving experiments
    first, idle ``maintain()`` debt last).

    Jobs are two-phase (``Optimizer.fit_job``): the expensive compute
    runs WITHOUT the experiment's optimizer lock (pure JAX over a
    snapshot), and only the cheap install step takes the lock — so a
    fit in flight never blocks the request path.

    One instance serves the whole process (``fit_executor()``); workers
    are daemon threads, so tests and short-lived CLIs need no teardown.
    ``submit`` coalesces by key (one outstanding job per experiment,
    escalating to the highest requested priority), which bounds the
    queue at O(live experiments)."""

    #: idle wait between queue polls (wakes are event-driven via submit)
    IDLE_WAIT = 0.25

    #: how long a non-urgent batchable fit waits for co-batchable peers
    #: to arrive before dispatching (seconds).  PRIO_MISS fits never
    #: wait — a request is parked on that fit's install.
    GATHER_WINDOW = 0.02

    #: bounds for the *dynamic* co-batch width (``max_lanes``): the cap
    #: on experiments fitted in one batched dispatch is sized from the
    #: executor's own saturation signals (backlog per worker, duty
    #: cycle) and rounded to a power of two so every width lands on a
    #: ``gp.lane_pad`` compile bucket
    LANES_MIN = 2
    LANES_CAP = 16

    #: legacy pin: when set, overrides the dynamic sizing with a fixed
    #: cap (tests pin this to make batch widths deterministic)
    MAX_LANES: Optional[int] = None

    #: window (seconds) over which the duty cycle decays — admission
    #: control wants *recent* saturation, not the lifetime average
    DUTY_WINDOW = 30.0

    def __init__(self, workers: Optional[int] = None):
        if workers is None:
            # a small shared pool: fits saturate cores (JAX releases the
            # GIL), so more workers than ~cpu/4 just thrash the caches
            workers = max(1, min(2, (os.cpu_count() or 2) // 4))
        self.workers = workers
        self._cv = threading.Condition()
        self._heap: List[tuple] = []            # (prio, seq, key)
        self._jobs: Dict[Any, tuple] = {}       # key -> (prio, fn)
        self._active: set = set()               # keys running on a worker
        self._seq = 0
        self._stopped = False
        self.stats = {"executed": 0, "coalesced": 0, "requeued": 0,
                      "batched": 0, "lanes": 0,
                      "batched_asks": 0, "ask_lanes": 0}
        # duty-cycle accounting (the fleet's admission-control signal):
        # busy worker-seconds, decayed over DUTY_WINDOW so a burst of
        # fits shows up — and clears — within one window
        self._duty_busy = 0.0
        self._duty_mark = time.monotonic()
        self._threads = [
            threading.Thread(target=self._run, name=f"fit-exec-{i}",
                             daemon=True)
            for i in range(workers)]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------- queue
    def submit(self, key: Any, fn: Callable[[], bool],
               prio: int = PRIO_IDLE) -> None:
        """Queue ``fn`` under ``key``; one job per key is outstanding at
        a time (re-submits coalesce, keeping the most recent ``fn`` and
        the most urgent priority).  ``fn`` runs on a worker thread and
        returns True to be requeued (e.g. it lost an optimizer-lock
        race)."""
        with self._cv:
            if self._stopped:
                return
            if key in self._active:
                # this key's job is mid-run on a worker: don't queue a
                # second fit for the same experiment (the debt check is
                # level-triggered — the pump re-submits on a later tick
                # once the running fit has installed, if still owed)
                self.stats["coalesced"] += 1
                return
            cur = self._jobs.get(key)
            if cur is not None:
                self.stats["coalesced"] += 1
                if prio < cur[0]:       # escalate: push a fresher entry;
                    self._jobs[key] = (prio, fn)    # the stale one is
                    self._seq += 1                  # skipped at pop time
                    heapq.heappush(self._heap, (prio, self._seq, key))
                    self._cv.notify()
                else:
                    self._jobs[key] = (cur[0], fn)
                return
            self._jobs[key] = (prio, fn)
            self._seq += 1
            heapq.heappush(self._heap, (prio, self._seq, key))
            self._cv.notify()

    def cancel(self, key: Any) -> bool:
        """Drop the outstanding job for ``key`` (experiment stopped)."""
        with self._cv:
            return self._jobs.pop(key, None) is not None

    def backlog(self) -> int:
        with self._cv:
            return len(self._jobs)

    @property
    def alive(self) -> bool:
        return not self._stopped and any(t.is_alive() for t in self._threads)

    def stop(self, join: bool = True) -> None:
        """Tear down (tests only — the process-wide singleton normally
        lives as long as the process; its threads are daemons)."""
        with self._cv:
            self._stopped = True
            self._jobs.clear()
            self._heap.clear()
            self._cv.notify_all()
        if join:
            for t in self._threads:
                if t is not threading.current_thread():
                    t.join(timeout=5.0)

    def _decay_duty(self, now: float) -> None:
        """Exponential decay of the busy accumulator (holding _cv)."""
        dt = now - self._duty_mark
        if dt > 0:
            self._duty_busy *= 0.5 ** (dt / self.DUTY_WINDOW)
            self._duty_mark = now

    def duty(self) -> float:
        """Fraction of worker capacity spent running fits over the recent
        window, in [0, 1] — together with ``backlog`` this is the shard
        saturation signal the FleetManager admits against."""
        with self._cv:
            now = time.monotonic()
            self._decay_duty(now)
            # a freshly-started executor has no window yet; normalize by
            # the half-life-weighted capacity of the window
            cap = self.workers * self.DUTY_WINDOW / 2.0
            return min(1.0, self._duty_busy / cap) if cap > 0 else 0.0

    def _max_lanes_locked(self, duty: float) -> int:
        """Dynamic co-batch cap (holding ``_cv``): aim to clear the
        current backlog in one dispatch round per worker, doubling when
        the recent duty cycle says the pool is saturated (bigger batches
        amortize better exactly when dispatches are the bottleneck);
        round up to a power of two (compile-bucket alignment), clamp to
        [LANES_MIN, LANES_CAP]."""
        if self.MAX_LANES is not None:
            return self.MAX_LANES
        want = (len(self._jobs) + self.workers - 1) // max(1, self.workers)
        if duty >= 0.5:
            want *= 2
        lanes = self.LANES_MIN
        while lanes < want and lanes < self.LANES_CAP:
            lanes *= 2
        return lanes

    def max_lanes(self) -> int:
        """Current cap on experiments co-batched into one dispatch."""
        with self._cv:
            now = time.monotonic()
            self._decay_duty(now)
            cap = self.workers * self.DUTY_WINDOW / 2.0
            duty = min(1.0, self._duty_busy / cap) if cap > 0 else 0.0
            return self._max_lanes_locked(duty)

    def snapshot(self) -> Dict[str, Any]:
        with self._cv:
            now = time.monotonic()
            self._decay_duty(now)
            cap = self.workers * self.DUTY_WINDOW / 2.0
            duty = min(1.0, self._duty_busy / cap) if cap > 0 else 0.0
            batched = self.stats["batched"]
            mean_batch = (round(self.stats["lanes"] / batched, 3)
                          if batched else 0.0)
            b_asks = self.stats["batched_asks"]
            mean_ask_batch = (round(self.stats["ask_lanes"] / b_asks, 3)
                              if b_asks else 0.0)
            return dict(self.stats, backlog=len(self._jobs),
                        workers=self.workers, duty=round(duty, 4),
                        mean_batch=mean_batch,
                        mean_ask_batch=mean_ask_batch,
                        max_lanes=self._max_lanes_locked(duty))

    # ----------------------------------------------------------- workers
    def _pop(self):
        """Highest-priority live job, or None after an idle wait.  Heap
        entries whose key was cancelled/coalesced away (priority no
        longer matching) are lazily skipped."""
        with self._cv:
            while not self._stopped:
                while self._heap:
                    prio, _, key = heapq.heappop(self._heap)
                    cur = self._jobs.get(key)
                    if cur is not None and cur[0] == prio:
                        del self._jobs[key]
                        self._active.add(key)
                        return key, cur[1], prio
                self._cv.wait(self.IDLE_WAIT)
                if not self._heap:
                    return None
            return None

    def _run(self) -> None:
        while True:
            item = self._pop()
            if item is None:
                if self._stopped:
                    return
                continue
            key, fn, prio = item
            err = None
            sleep_adj = 0.0
            t0 = time.monotonic()
            try:
                if isinstance(fn, BatchableFit):
                    again, sleep_adj = self._run_batch(key, fn, prio)
                else:
                    again = bool(fn())
            except Exception as e:  # noqa: executor must survive any job
                again = False
                err = f"{type(e).__name__}: {e}"
            with self._cv:
                self._active.discard(key)   # before any re-submit
                self._decay_duty(time.monotonic())
                # gather-window sleeps are idle time, not fit work —
                # they must not inflate the admission-control duty cycle
                self._duty_busy += max(
                    0.0, time.monotonic() - t0 - sleep_adj)
                self.stats["executed"] += 1
                if again:
                    self.stats["requeued"] += 1
                if err is not None:
                    # surfaced via snapshot()/StatusResponse — a
                    # persistently failing fit must not die silently
                    # (the pump keeps re-submitting while debt is owed)
                    self.stats["failed"] = self.stats.get("failed", 0) + 1
                    self.stats["last_error"] = err
            if again:
                self.submit(key, fn, prio)

    def _run_batch(self, key: Any, fn: BatchableFit,
                   prio: int) -> tuple:
        """Execute one batchable fit, co-batching queued peers (ISSUE 8).

        Snapshot the primary lane; unless the fit is miss-urgent, sleep
        one GATHER_WINDOW so concurrently-owed experiments can queue;
        then pull every queued ``BatchableFit`` whose snapshot shares
        the primary's (runner, bucket, steps) group and dispatch them
        all through ONE ``runner(specs)`` call — the optimizer stacks
        the lanes and runs the Adam loop vmap'd, so k fits cost one XLA
        dispatch instead of k.  Installs run per lane, individually
        exception-guarded, each under its own experiment's optimizer
        lock (the PR 5 two-phase contract is per lane, unchanged).

        Returns (requeue_primary, seconds_slept) — the sleep is
        subtracted from the duty-cycle accounting by ``_run``."""
        lane = fn.snapshot()
        if lane is RETRY:
            return True, 0.0
        if lane is None:
            return False, 0.0
        slept = 0.0
        if prio > PRIO_MISS and self.GATHER_WINDOW > 0.0:
            # deliberate plain sleep (not a _cv wait): we *want* to stay
            # out of the way while pumps enqueue peers
            time.sleep(self.GATHER_WINDOW)
            slept = self.GATHER_WINDOW
        grabbed: List[tuple] = []
        lanes_cap = self.max_lanes()
        with self._cv:
            for k2 in list(self._jobs):
                if 1 + len(grabbed) >= lanes_cap:
                    break
                p2, f2 = self._jobs[k2]
                if isinstance(f2, BatchableFit):
                    del self._jobs[k2]
                    self._active.add(k2)
                    grabbed.append((k2, p2, f2))
        lanes = [(key, lane)]
        for k2, p2, f2 in grabbed:
            try:
                l2 = f2.snapshot()
            except Exception as e:  # noqa: peer snapshot must not kill batch
                with self._cv:
                    self._active.discard(k2)
                    self.stats["failed"] = self.stats.get("failed", 0) + 1
                    self.stats["last_error"] = f"{type(e).__name__}: {e}"
                continue
            if (l2 is not None and l2 is not RETRY
                    and l2.group_key == lane.group_key):
                lanes.append((k2, l2))
                continue
            # not co-batchable: release the key BEFORE re-submitting so
            # submit() doesn't coalesce the job away as "active"
            with self._cv:
                self._active.discard(k2)
            if l2 is not None:      # RETRY or mismatched group: still owed
                self.submit(k2, f2, p2)
        try:
            out, dt = lane.spec.runner([l.spec for _, l in lanes])
            per = dt / max(1, len(lanes))
            failed = 0
            err = None
            for (_, l), params in zip(lanes, out):
                try:
                    l.install(params, per)
                except Exception as e:  # noqa: one bad install ≠ batch loss
                    failed += 1
                    err = f"{type(e).__name__}: {e}"
            is_ask = getattr(lane.spec, "kind", "fit") == "ask"
            with self._cv:
                # fit and ask dispatches count separately, so mean_batch
                # stays a pure fit-co-batching signal (tests pin it)
                self.stats["batched_asks" if is_ask else "batched"] += 1
                self.stats["ask_lanes" if is_ask else "lanes"] += len(lanes)
                # _run counts the primary; peers are accounted here
                self.stats["executed"] += len(lanes) - 1
                if failed:
                    self.stats["failed"] = (
                        self.stats.get("failed", 0) + failed)
                    self.stats["last_error"] = err
        finally:
            with self._cv:
                for k2, _ in lanes[1:]:
                    self._active.discard(k2)
        return False, slept


_EXECUTOR: Optional[FitExecutor] = None
_EXECUTOR_LOCK = threading.Lock()


@atexit.register
def _shutdown_executor() -> None:
    """Drain the executor before interpreter teardown.  Its workers are
    daemon threads running XLA dispatches; since the batched ask plane
    (ISSUE 10) keeps them busy whenever any queue is below depth, a
    process exiting mid-dispatch would abort inside the XLA runtime
    ("terminate called without an active exception") instead of exiting
    cleanly.  stop() discards the queue and joins the in-flight job."""
    ex = _EXECUTOR
    if ex is not None and ex.alive:
        ex.stop(join=True)


def fit_executor() -> FitExecutor:
    """The process-wide fit executor (created on first use; replaced if a
    test stopped the previous one)."""
    global _EXECUTOR
    with _EXECUTOR_LOCK:
        if _EXECUTOR is None or not _EXECUTOR.alive:
            _EXECUTOR = FitExecutor()
        return _EXECUTOR


def cancel_fit(key: Any) -> None:
    """Cancel a queued fit without instantiating the executor (pump
    teardown on processes that never submitted a fit)."""
    ex = _EXECUTOR
    if ex is not None and ex.alive:
        ex.cancel(key)


def executor_snapshot() -> Optional[Dict[str, Any]]:
    """The live executor's counters, or None — status/monitoring reads
    must not spawn the worker pool as a side effect."""
    ex = _EXECUTOR
    if ex is not None and ex.alive:
        return ex.snapshot()
    return None


class PrefetchItem:
    """One speculative suggestion waiting in the pump queue.  ``sparse``
    marks entries minted from the sparse subset-of-data posterior (queue
    refills under saturation) rather than the exact one."""
    __slots__ = ("assignment", "born_obs", "sparse")

    def __init__(self, assignment: Dict[str, Any], born_obs: int,
                 sparse: bool = False):
        self.assignment = assignment
        self.born_obs = born_obs
        self.sparse = sparse


class MissSlot:
    """A ``suggest`` call waiting out a queue miss.  Filled (with up to
    ``need`` suggestions — possibly fewer, budget permitting) by whichever
    thread wins the optimizer lock and serves the coalesced batch."""
    __slots__ = ("need", "event", "result", "done")

    def __init__(self, need: int):
        self.need = need
        self.event = threading.Event()
        self.result: List[Any] = []
        self.done = False


def drain_ops(state) -> int:
    """Apply the deferred optimizer operations (observation folds and lie
    retirements that ``observe``/``release`` enqueued).  MUST be called
    with ``state.opt_lock`` held; pops under ``state.lock`` so no op is
    ever in flight outside both locks.  Returns the number applied."""
    with state.lock:
        ops, state.ops = state.ops, []
    if not ops:
        return 0
    tells: List[Any] = []
    for kind, payload in ops:
        if kind == "tell":
            tells.append(payload)
        else:                           # "forget"
            if tells:
                state.optimizer.tell(tells)
                tells = []
            state.optimizer.forget(payload)
    if tells:
        state.optimizer.tell(tells)
    return len(ops)


def pop_prefetched(state, want: int):
    """Pop up to ``want`` fresh queue items; returns (fresh
    ``PrefetchItem``s, stale assignments).  MUST be called with
    ``state.lock`` held.  Stale items (older than the K-observation
    staleness bound) are skimmed off and returned for lie retirement —
    they are never served.  Fresh items keep their ``sparse`` flag so
    the mint step can attribute the served suggestion to the exact or
    approximate posterior (the SPARSE_MAX quality counters)."""
    fresh: List[PrefetchItem] = []
    stale: List[Dict[str, Any]] = []
    sparse_served = 0
    while state.queue and len(fresh) < want:
        # LIFO: always serve the *freshest* speculation — it was computed
        # against the most observations.  Older entries age toward the
        # staleness bound at the front and are swept by the pump.
        item = state.queue.pop()
        if state.observed - item.born_obs >= state.staleness:
            stale.append(item.assignment)
        else:
            fresh.append(item)
            sparse_served += bool(item.sparse)
    if stale:
        state.stats["invalidated"] += len(stale)
    if fresh:
        state.stats["hits"] += len(fresh)
    if sparse_served:
        # how much of the served traffic rode the approximate posterior —
        # the signal for tuning SPARSE_MAX (ROADMAP: sparse quality)
        state.stats["sparse_served"] = (
            state.stats.get("sparse_served", 0) + sparse_served)
    return fresh, stale


def retire_queue(state, terminal_only: bool = False) -> int:
    """Flush the prefetch queue and retire its constant-liar lies.  MUST
    be called with ``state.opt_lock`` held.  With ``terminal_only`` the
    flush only happens once the experiment can't serve again (stopped or
    budget spent) — the shared hygiene used by the pump's wind-down,
    ``status()`` and ``stop()``.  Returns the number retired."""
    with state.lock:
        if terminal_only and not (state.stopped
                                  or state.observed >= state.cfg.budget):
            return 0
        doomed = [i.assignment for i in state.queue]
        state.queue = []
        if doomed:
            state.stats["invalidated"] += len(doomed)
    for a in doomed:
        state.optimizer.forget(a)
    return len(doomed)


def serve_misses(state, make_suggestion: Callable[[Dict[str, Any]], Any]) -> int:
    """Serve parked `MissSlot`s with ONE batched ``ask`` (cross-scheduler
    request coalescing: concurrent queue misses share one model pass, not
    N serialized ones).  MUST be called with ``state.opt_lock`` held.
    ``make_suggestion`` mints a pending Suggestion from an assignment —
    called under ``state.lock``.  A round serves up to ``ASK_CHUNK``
    suggestions (the first slot is always taken whole); overflow slots
    stay parked for the next lock winner — usually their own waiting
    thread's retry loop.  Returns the number of slots served."""
    drain_ops(state)
    with state.lock:
        waiting = [s for s in state.miss_slots if not s.done]
        slots, acc = [], 0
        for s in waiting:
            if slots and acc + s.need > ASK_CHUNK:
                break
            slots.append(s)
            acc += s.need
        state.miss_slots = waiting[len(slots):]
        if not slots:
            return 0
        if state.stopped:
            total = 0
        else:
            headroom = (state.cfg.budget - state.observed
                        - len(state.pending))
            total = min(sum(s.need for s in slots), max(0, headroom))
    assigns = state.optimizer.ask(total) if total > 0 else []
    with state.lock:
        # headroom may have shrunk while we computed (queue pops register
        # pending under state.lock only) — never overdraw the budget
        headroom = state.cfg.budget - state.observed - len(state.pending)
        if state.stopped:
            headroom = 0
        usable = assigns[:max(0, headroom)]
        extra = assigns[len(usable):]
        i = 0
        for slot in slots:
            take = usable[i:i + slot.need]
            i += len(take)
            slot.result = [make_suggestion(a) for a in take]
            slot.done = True
            slot.event.set()
        extra.extend(usable[i:])
        if len(slots) > 1:
            state.stats["coalesced"] += len(slots) - 1
        state.stats["misses"] += len(slots)
    for a in extra:     # opt_lock still held
        state.optimizer.forget(a)
    return len(slots)


class SuggestionPump:
    """Per-experiment background worker: folds deferred observations,
    refits the model, prewarms compile buckets, invalidates stale queue
    entries, and keeps the prefetch queue at ``depth``.  Owns no locks of
    its own — it speaks the same ``opt_lock``/``state.lock`` protocol as
    the request path, always acquiring ``opt_lock`` with a timeout so
    ``stop()`` stays responsive even mid-fit."""

    #: fallback poll period — wakes are event-driven (observe/suggest/stop)
    IDLE_WAIT = 0.25

    def __init__(self, state, exp_id: str, depth: int,
                 make_suggestion: Callable[[Dict[str, Any]], Any]):
        self.state = state
        self.exp_id = exp_id
        self.depth = depth
        self.make_suggestion = make_suggestion
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._prewarm_goal = 0
        # miss counter at the last tick — the saturation signal.  Seeded
        # from the state so a restarted pump (close/resume reuses the
        # _ExperimentState) doesn't read pre-restart misses as live
        # saturation and serve sparse refills on an idle service.
        self._seen_misses = state.stats.get("misses", 0)
        self._thread = threading.Thread(
            target=self._run, name=f"suggest-pump-{exp_id}", daemon=True)

    @property
    def fit_key(self) -> tuple:
        """This experiment's coalescing key on the shared FitExecutor."""
        return ("fit", id(self.state))

    @property
    def ask_key(self) -> tuple:
        """Coalescing key of this experiment's batched refill ask — a
        separate key from ``fit_key`` so a queued refill never coalesces
        away an owed hyperfit (or vice versa)."""
        return ("ask", id(self.state))

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "SuggestionPump":
        self._thread.start()
        return self

    def wake(self) -> None:
        self._wake.set()

    def stop(self, join: bool = True, timeout: float = 10.0) -> None:
        self._stop.set()
        self._wake.set()
        cancel_fit(self.fit_key)
        cancel_fit(self.ask_key)
        if join and self._thread.is_alive() \
                and self._thread is not threading.current_thread():
            self._thread.join(timeout)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive() and not self._stop.is_set()

    # ------------------------------------------------------------ internals
    def _run(self) -> None:
        state = self.state
        # pipeline mode: ask() folds new data by cheap recondition; the
        # hyperparameter refits run here, in maintain(), when quiet
        state.optimizer.defer_fits = True
        try:
            self._prewarm()
            while not self._stop.is_set():
                busy = self._tick()
                if self._stop.is_set() or self._finished():
                    break
                if not busy:
                    self._wake.wait(self.IDLE_WAIT)
                    self._wake.clear()
        except Exception as e:  # noqa: pump death must not kill the service
            with state.lock:
                state.stats["pump_error"] = f"{type(e).__name__}: {e}"
        finally:
            # back to synchronous semantics for any pump-less aftermath
            state.optimizer.defer_fits = False

    def _finished(self) -> bool:
        state = self.state
        with state.lock:
            return state.stopped or state.observed >= state.cfg.budget

    def _prewarm(self) -> None:
        """Compile the shape buckets the near-term asks will need.  Reads
        only immutable optimizer config + jit caches — runs without
        ``opt_lock`` so the first suggests aren't blocked behind compiles."""
        state = self.state
        with state.lock:
            n = (state.observed + len(state.pending) + len(state.queue)
                 + self.depth + 8)
            goal = min(max(n, 1), state.cfg.budget + self.depth)
        if goal <= self._prewarm_goal:
            return
        self._prewarm_goal = goal
        warmed = state.optimizer.prewarm(goal, batch=min(self.depth, 8))
        if warmed:
            with state.lock:
                state.stats["prewarmed"] += warmed

    def _tick(self) -> bool:
        """One unit of pump work; returns True when anything was done (the
        loop re-ticks immediately) and False to idle-wait.  Hyperfits are
        NOT run here: debt is submitted to the shared ``FitExecutor`` so
        the pump thread only reconditions and pops."""
        state = self.state
        self._prewarm()     # cheap no-op once the goal bucket is compiled
        if not state.opt_lock.acquire(timeout=0.1):
            return True     # contended: re-check stop flag, then retry
        try:
            if self._stop.is_set():
                return False
            busy = drain_ops(state) > 0
            # a parked miss means the queue is already dry — serve it first
            busy = serve_misses(state, self.make_suggestion) > 0 or busy
            # terminal: nothing more will be served — retire the whole
            # queue's lies and let the thread wind down
            retired = retire_queue(state, terminal_only=True)
            # prune stale speculation, then top the queue back up
            with state.lock:
                stale = [i.assignment for i in state.queue
                         if state.observed - i.born_obs >= state.staleness]
                if stale:
                    state.queue = [
                        i for i in state.queue
                        if state.observed - i.born_obs < state.staleness]
                    state.stats["invalidated"] += len(stale)
                if state.stopped or state.observed >= state.cfg.budget:
                    want = 0
                else:
                    headroom = (state.cfg.budget - state.observed
                                - len(state.pending) - len(state.queue))
                    # chunked refill: bounded lock hold + bounded q-EI
                    # scan shapes; the loop re-ticks until at depth
                    want = min(self.depth - len(state.queue),
                               max(0, headroom), ASK_CHUNK)
                # saturation signal: requests outran the warm queue since
                # the last tick (served misses, or slots parked right now)
                misses_now = state.stats["misses"]
                saturated = (misses_now > self._seen_misses
                             or bool(state.miss_slots))
                self._seen_misses = misses_now
            for a in stale:
                state.optimizer.forget(a)
            swept = bool(stale) or retired > 0
            self._tune_sparse()
            self._push_fit_debt(saturated, want)
            if want <= 0:
                return busy or swept
            # under saturation a speculative_ask optimizer refills from
            # its sparse posterior — bounded cost regardless of history
            # size, so the queue keeps pace past refit-bound throughput;
            # misses and synchronous asks still use the exact path.
            # sparse_eligible() confirms the sparse path would really
            # engage (enough history, fitted model), so the sparse_*
            # counters never mislabel exact suggestions
            spec = (saturated
                    and getattr(state.optimizer, "speculative_ask", False)
                    and state.optimizer.sparse_eligible())
            if (getattr(state.optimizer, "batchable_asks", False)
                    and state.optimizer.ask_spec_ready()):
                # batched ask plane (ISSUE 10): publish the refill as a
                # batchable snapshot on the shared executor, which may
                # co-batch it with other experiments' refills into ONE
                # vmap'd q-EI dispatch; its install callback fills the
                # queue and wakes this pump.  Misses never ride this
                # path — serve_misses above keeps its exact inline ask.
                fit_executor().submit(
                    self.ask_key,
                    BatchableAsk(lambda: self._ask_lane(spec)),
                    PRIO_REFILL)
                return busy or swept
            assigns = (state.optimizer.ask(want, speculative=True)
                       if spec else state.optimizer.ask(want))
            with state.lock:
                if state.stopped or state.observed >= state.cfg.budget:
                    take = []
                else:
                    headroom = (state.cfg.budget - state.observed
                                - len(state.pending) - len(state.queue))
                    take = assigns[:max(0, headroom)]
                state.queue.extend(
                    PrefetchItem(a, state.observed, sparse=spec)
                    for a in take)
                state.stats["prefilled"] += len(take)
                if spec:
                    state.stats["sparse_prefilled"] = (
                        state.stats.get("sparse_prefilled", 0) + len(take))
                extra = assigns[len(take):]
            for a in extra:
                state.optimizer.forget(a)
            return True
        finally:
            state.opt_lock.release()

    def _tune_sparse(self) -> None:
        """Feed the service's sparse-vs-exact suggestion quality counters
        back into the optimizer's live sparse-subset budget (closes the
        PR 5 follow-up: SPARSE_MAX was a fixed constant; now
        ``Optimizer.tune_sparse`` grows/shrinks it from observed regret).
        Called with ``opt_lock`` held."""
        tune = getattr(self.state.optimizer, "tune_sparse", None)
        if tune is None:
            return
        state = self.state
        with state.lock:
            quality = {k: state.stats.get(k, 0)
                       for k in ("sparse_obs", "sparse_regret",
                                 "exact_obs", "exact_regret")}
        tune(quality)

    def _push_fit_debt(self, saturated: bool, want: int) -> None:
        """Submit owed hyperfit work to the shared executor, prioritized
        by how starved this experiment is.  Called with ``opt_lock``
        held (``maintenance_due`` reads optimizer state).  Optimizers
        that publish batchable fit descriptors (``batchable_fits``) go
        through the co-batching path (ISSUE 8); the rest keep the plain
        two-phase ``fit_job`` contract."""
        if not self.state.optimizer.maintenance_due():
            return
        prio = (PRIO_MISS if saturated
                else PRIO_REFILL if want > 0 else PRIO_IDLE)
        if getattr(self.state.optimizer, "batchable_fits", False):
            fit_executor().submit(self.fit_key,
                                  BatchableFit(self._fit_lane), prio)
        else:
            fit_executor().submit(self.fit_key, self._maintain_job, prio)

    def _fit_lane(self):
        """Snapshot this experiment's owed fit as a batchable lane
        (``FitExecutor._run_batch``'s snapshot phase).  Returns a
        ``FitLane``, ``RETRY`` on optimizer-lock contention, or None
        when the debt has already been paid.  The lane's install runs
        later on the executor thread, under ``opt_lock`` — the same
        two-phase contract as ``_maintain_job``, split so the compute
        phase can be shared across experiments."""
        state = self.state
        if self._stop.is_set():
            return None
        if not state.opt_lock.acquire(timeout=0.05):
            return None if self._stop.is_set() else RETRY
        try:
            drain_ops(state)            # the fit should see every fold
            spec = state.optimizer.fit_spec()
        finally:
            state.opt_lock.release()
        if spec is None:
            return None

        def install(params, dt):
            with state.opt_lock:
                if self._stop.is_set():
                    return
                spec.install(params, dt)
                with state.lock:
                    state.stats["maintained"] = (
                        state.stats.get("maintained", 0) + 1)
        return FitLane(spec, install)

    def _ask_lane(self, speculative: bool):
        """Snapshot this experiment's queue refill as a batchable ask
        lane (ISSUE 10).  Phase 1, here: under ``opt_lock``, drain the
        deferred folds, recompute the refill budget (``want`` may have
        shrunk since the tick that submitted us), and let the optimizer
        snapshot an ``AskSpec`` — posterior prepared, selection
        deferred.  Returns a ``FitLane``, ``RETRY`` on lock contention,
        or None when no refill is owed anymore.  Phase 2 (the q-EI
        scan) runs lock-free on the executor, possibly co-batched;
        phase 3 — the install below — mints the assignments and
        extends the queue under this experiment's own locks."""
        state = self.state
        if self._stop.is_set():
            return None
        if not state.opt_lock.acquire(timeout=0.05):
            return None if self._stop.is_set() else RETRY
        try:
            drain_ops(state)
            with state.lock:
                if state.stopped or state.observed >= state.cfg.budget:
                    return None
                headroom = (state.cfg.budget - state.observed
                            - len(state.pending) - len(state.queue))
                want = min(self.depth - len(state.queue),
                           max(0, headroom), ASK_CHUNK)
                born = state.observed
            if want <= 0:
                return None
            spec = state.optimizer.ask_spec(want, speculative=speculative)
        finally:
            state.opt_lock.release()
        if spec is None:
            return None
        inner = spec.install
        sparse = spec.sparse

        def install(result, dt):
            with state.opt_lock:
                if self._stop.is_set():
                    return
                assigns = inner(result, dt)
                with state.lock:
                    if state.stopped or state.observed >= state.cfg.budget:
                        take = []
                    else:
                        headroom = (state.cfg.budget - state.observed
                                    - len(state.pending) - len(state.queue))
                        take = assigns[:max(0, headroom)]
                    # born is the snapshot-time observation count: the
                    # staleness clock starts when the posterior was
                    # captured, not when the dispatch landed
                    state.queue.extend(
                        PrefetchItem(a, born, sparse=sparse) for a in take)
                    state.stats["prefilled"] += len(take)
                    state.stats["batched_prefilled"] = (
                        state.stats.get("batched_prefilled", 0) + len(take))
                    if sparse:
                        state.stats["sparse_prefilled"] = (
                            state.stats.get("sparse_prefilled", 0)
                            + len(take))
                    extra = assigns[len(take):]
                for a in extra:
                    state.optimizer.forget(a)
            self._wake.set()
        return FitLane(spec, install)

    def _maintain_job(self) -> bool:
        """One deferred hyperfit, run on the shared FitExecutor.  Phase
        1 snapshots the fit under ``opt_lock`` (cheap), phase 2 runs the
        Adam loop with NO lock held, phase 3 installs the result under
        ``opt_lock`` (cheap) — requests never wait behind the fit
        itself.  Returns True to be requeued after losing the lock
        race."""
        state = self.state
        if self._stop.is_set():
            return False
        if not state.opt_lock.acquire(timeout=0.05):
            return not self._stop.is_set()
        try:
            drain_ops(state)            # the fit should see every fold
            job = state.optimizer.fit_job()
        finally:
            state.opt_lock.release()
        if job is None:
            return False
        install = job()                 # the expensive part — lock-free
        with state.opt_lock:
            if not self._stop.is_set():
                install()
                with state.lock:
                    state.stats["maintained"] = (
                        state.stats.get("maintained", 0) + 1)
        return False
