"""Service-side asynchronous suggestion pipeline (prefetch pump + miss
coalescing) — the machinery that makes ``LocalClient.suggest`` latency
independent of model cost.

Three cooperating pieces (all operating on one ``_ExperimentState``):

* **Prefetch pump** (`SuggestionPump`): a per-experiment background thread
  that keeps a bounded queue of speculative suggestions warm.  Each queued
  suggestion was produced by a real ``ask()`` (so it carries its
  constant-liar ``__lie`` token and EI already accounts for it); the pump
  also absorbs the *deferred optimizer work* — observation folds,
  hyperparameter refits, lie retirement — that ``observe``/``release``
  only enqueue.  Cold-start XLA compile cost is moved off-path too: the
  pump prewarms the power-of-two GP shape buckets at start and again
  before the history crosses into the next bucket.

* **Miss coalescing** (`serve_misses`): concurrent ``suggest`` calls that
  find the queue dry park a `MissSlot` and race for the optimizer lock;
  the winner serves *every* parked slot with a single batched ``ask(n)``
  instead of N serialized model fits.  Losers wait on their slot's event
  — they never touch the optimizer.

* **Staleness bound**: every queued suggestion remembers the observation
  count it was computed at (``born_obs``).  Once ``staleness`` (K) new
  observations have arrived, the suggestion is *invalidated* — dropped at
  pop time (and proactively by the pump), its constant-liar lie retired —
  so a warm queue can never serve a point the model has since learned to
  avoid.  The same bound is what makes *sparse* refills safe: under
  saturation the pump refills from the optimizer's approximate
  subset-of-data posterior (``ask(n, speculative=True)``), and any
  approximation error is confined to queue entries at most K
  observations old.

* **Shared fit executor** (`FitExecutor`): hyperparameter-fit debt is
  never paid on a pump thread.  Pumps submit it to one process-wide
  priority-queue executor (miss-serving experiments first, idle
  maintenance last) whose workers run the fit compute without holding
  the experiment's optimizer lock (``Optimizer.fit_job``) — so N live
  experiments stop burning N cores on Adam loops while requests park.

Locking protocol (shared with ``repro.api.local``): ``state.opt_lock``
serializes all optimizer access (ask/tell/forget/restore) and must be
acquired *before* ``state.lock`` (cheap bookkeeping) when both are held.
``state.ops`` — the deferred tell/forget queue — is only ever popped
while holding ``opt_lock`` (see ``drain_ops``), which is what makes
create/resume's "drain then replay the log tail" sequence race-free.
"""
from __future__ import annotations

import heapq
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

#: Largest ``ask`` the pipeline issues per optimizer-lock hold (pump
#: refill ticks and coalesced miss rounds alike).  Bounds lock latency
#: (a request arriving mid-batch waits one chunk, not one queue fill)
#: and pins the q-EI scan shapes to the power-of-two pads <= 8 — exactly
#: what ``prewarm`` compiles, so no batch size ever pays a first-touch
#: scan compile on the request path.  Coalesced misses beyond a chunk
#: stay parked and are served by the next lock winner in ~one cheap
#: recondition+scan round each (hyperfits are deferred to the pump).
#: Only a single ``suggest(count > 8)`` call exceeds the chunk.
ASK_CHUNK = 8

#: FitExecutor priorities (lower = sooner): a fit for an experiment whose
#: requests are parking on queue misses beats one whose queue merely needs
#: refilling, which beats idle maintenance debt.
PRIO_MISS, PRIO_REFILL, PRIO_IDLE = 0, 1, 2


class FitExecutor:
    """Process-wide executor for deferred hyperparameter fits (ISSUE 5).

    Before this existed every per-experiment pump ran its own
    ``Optimizer.maintain()`` inline: N live experiments meant N threads
    each burning a core on an Adam loop while suggest requests parked
    behind the optimizer lock.  Now pumps only recondition and pop —
    fits are *submitted* here, deduplicated per experiment, and run by a
    small shared worker pool in priority order (miss-serving experiments
    first, idle ``maintain()`` debt last).

    Jobs are two-phase (``Optimizer.fit_job``): the expensive compute
    runs WITHOUT the experiment's optimizer lock (pure JAX over a
    snapshot), and only the cheap install step takes the lock — so a
    fit in flight never blocks the request path.

    One instance serves the whole process (``fit_executor()``); workers
    are daemon threads, so tests and short-lived CLIs need no teardown.
    ``submit`` coalesces by key (one outstanding job per experiment,
    escalating to the highest requested priority), which bounds the
    queue at O(live experiments)."""

    #: idle wait between queue polls (wakes are event-driven via submit)
    IDLE_WAIT = 0.25

    #: window (seconds) over which the duty cycle decays — admission
    #: control wants *recent* saturation, not the lifetime average
    DUTY_WINDOW = 30.0

    def __init__(self, workers: Optional[int] = None):
        if workers is None:
            # a small shared pool: fits saturate cores (JAX releases the
            # GIL), so more workers than ~cpu/4 just thrash the caches
            workers = max(1, min(2, (os.cpu_count() or 2) // 4))
        self.workers = workers
        self._cv = threading.Condition()
        self._heap: List[tuple] = []            # (prio, seq, key)
        self._jobs: Dict[Any, tuple] = {}       # key -> (prio, fn)
        self._active: set = set()               # keys running on a worker
        self._seq = 0
        self._stopped = False
        self.stats = {"executed": 0, "coalesced": 0, "requeued": 0}
        # duty-cycle accounting (the fleet's admission-control signal):
        # busy worker-seconds, decayed over DUTY_WINDOW so a burst of
        # fits shows up — and clears — within one window
        self._duty_busy = 0.0
        self._duty_mark = time.monotonic()
        self._threads = [
            threading.Thread(target=self._run, name=f"fit-exec-{i}",
                             daemon=True)
            for i in range(workers)]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------- queue
    def submit(self, key: Any, fn: Callable[[], bool],
               prio: int = PRIO_IDLE) -> None:
        """Queue ``fn`` under ``key``; one job per key is outstanding at
        a time (re-submits coalesce, keeping the most recent ``fn`` and
        the most urgent priority).  ``fn`` runs on a worker thread and
        returns True to be requeued (e.g. it lost an optimizer-lock
        race)."""
        with self._cv:
            if self._stopped:
                return
            if key in self._active:
                # this key's job is mid-run on a worker: don't queue a
                # second fit for the same experiment (the debt check is
                # level-triggered — the pump re-submits on a later tick
                # once the running fit has installed, if still owed)
                self.stats["coalesced"] += 1
                return
            cur = self._jobs.get(key)
            if cur is not None:
                self.stats["coalesced"] += 1
                if prio < cur[0]:       # escalate: push a fresher entry;
                    self._jobs[key] = (prio, fn)    # the stale one is
                    self._seq += 1                  # skipped at pop time
                    heapq.heappush(self._heap, (prio, self._seq, key))
                    self._cv.notify()
                else:
                    self._jobs[key] = (cur[0], fn)
                return
            self._jobs[key] = (prio, fn)
            self._seq += 1
            heapq.heappush(self._heap, (prio, self._seq, key))
            self._cv.notify()

    def cancel(self, key: Any) -> bool:
        """Drop the outstanding job for ``key`` (experiment stopped)."""
        with self._cv:
            return self._jobs.pop(key, None) is not None

    def backlog(self) -> int:
        with self._cv:
            return len(self._jobs)

    @property
    def alive(self) -> bool:
        return not self._stopped and any(t.is_alive() for t in self._threads)

    def stop(self, join: bool = True) -> None:
        """Tear down (tests only — the process-wide singleton normally
        lives as long as the process; its threads are daemons)."""
        with self._cv:
            self._stopped = True
            self._jobs.clear()
            self._heap.clear()
            self._cv.notify_all()
        if join:
            for t in self._threads:
                if t is not threading.current_thread():
                    t.join(timeout=5.0)

    def _decay_duty(self, now: float) -> None:
        """Exponential decay of the busy accumulator (holding _cv)."""
        dt = now - self._duty_mark
        if dt > 0:
            self._duty_busy *= 0.5 ** (dt / self.DUTY_WINDOW)
            self._duty_mark = now

    def duty(self) -> float:
        """Fraction of worker capacity spent running fits over the recent
        window, in [0, 1] — together with ``backlog`` this is the shard
        saturation signal the FleetManager admits against."""
        with self._cv:
            now = time.monotonic()
            self._decay_duty(now)
            # a freshly-started executor has no window yet; normalize by
            # the half-life-weighted capacity of the window
            cap = self.workers * self.DUTY_WINDOW / 2.0
            return min(1.0, self._duty_busy / cap) if cap > 0 else 0.0

    def snapshot(self) -> Dict[str, Any]:
        with self._cv:
            now = time.monotonic()
            self._decay_duty(now)
            cap = self.workers * self.DUTY_WINDOW / 2.0
            duty = min(1.0, self._duty_busy / cap) if cap > 0 else 0.0
            return dict(self.stats, backlog=len(self._jobs),
                        workers=self.workers, duty=round(duty, 4))

    # ----------------------------------------------------------- workers
    def _pop(self):
        """Highest-priority live job, or None after an idle wait.  Heap
        entries whose key was cancelled/coalesced away (priority no
        longer matching) are lazily skipped."""
        with self._cv:
            while not self._stopped:
                while self._heap:
                    prio, _, key = heapq.heappop(self._heap)
                    cur = self._jobs.get(key)
                    if cur is not None and cur[0] == prio:
                        del self._jobs[key]
                        self._active.add(key)
                        return key, cur[1], prio
                self._cv.wait(self.IDLE_WAIT)
                if not self._heap:
                    return None
            return None

    def _run(self) -> None:
        while True:
            item = self._pop()
            if item is None:
                if self._stopped:
                    return
                continue
            key, fn, prio = item
            err = None
            t0 = time.monotonic()
            try:
                again = bool(fn())
            except Exception as e:  # noqa: executor must survive any job
                again = False
                err = f"{type(e).__name__}: {e}"
            with self._cv:
                self._active.discard(key)   # before any re-submit
                self._decay_duty(time.monotonic())
                self._duty_busy += time.monotonic() - t0
                self.stats["executed"] += 1
                if again:
                    self.stats["requeued"] += 1
                if err is not None:
                    # surfaced via snapshot()/StatusResponse — a
                    # persistently failing fit must not die silently
                    # (the pump keeps re-submitting while debt is owed)
                    self.stats["failed"] = self.stats.get("failed", 0) + 1
                    self.stats["last_error"] = err
            if again:
                self.submit(key, fn, prio)


_EXECUTOR: Optional[FitExecutor] = None
_EXECUTOR_LOCK = threading.Lock()


def fit_executor() -> FitExecutor:
    """The process-wide fit executor (created on first use; replaced if a
    test stopped the previous one)."""
    global _EXECUTOR
    with _EXECUTOR_LOCK:
        if _EXECUTOR is None or not _EXECUTOR.alive:
            _EXECUTOR = FitExecutor()
        return _EXECUTOR


def cancel_fit(key: Any) -> None:
    """Cancel a queued fit without instantiating the executor (pump
    teardown on processes that never submitted a fit)."""
    ex = _EXECUTOR
    if ex is not None and ex.alive:
        ex.cancel(key)


def executor_snapshot() -> Optional[Dict[str, Any]]:
    """The live executor's counters, or None — status/monitoring reads
    must not spawn the worker pool as a side effect."""
    ex = _EXECUTOR
    if ex is not None and ex.alive:
        return ex.snapshot()
    return None


class PrefetchItem:
    """One speculative suggestion waiting in the pump queue.  ``sparse``
    marks entries minted from the sparse subset-of-data posterior (queue
    refills under saturation) rather than the exact one."""
    __slots__ = ("assignment", "born_obs", "sparse")

    def __init__(self, assignment: Dict[str, Any], born_obs: int,
                 sparse: bool = False):
        self.assignment = assignment
        self.born_obs = born_obs
        self.sparse = sparse


class MissSlot:
    """A ``suggest`` call waiting out a queue miss.  Filled (with up to
    ``need`` suggestions — possibly fewer, budget permitting) by whichever
    thread wins the optimizer lock and serves the coalesced batch."""
    __slots__ = ("need", "event", "result", "done")

    def __init__(self, need: int):
        self.need = need
        self.event = threading.Event()
        self.result: List[Any] = []
        self.done = False


def drain_ops(state) -> int:
    """Apply the deferred optimizer operations (observation folds and lie
    retirements that ``observe``/``release`` enqueued).  MUST be called
    with ``state.opt_lock`` held; pops under ``state.lock`` so no op is
    ever in flight outside both locks.  Returns the number applied."""
    with state.lock:
        ops, state.ops = state.ops, []
    if not ops:
        return 0
    tells: List[Any] = []
    for kind, payload in ops:
        if kind == "tell":
            tells.append(payload)
        else:                           # "forget"
            if tells:
                state.optimizer.tell(tells)
                tells = []
            state.optimizer.forget(payload)
    if tells:
        state.optimizer.tell(tells)
    return len(ops)


def pop_prefetched(state, want: int):
    """Pop up to ``want`` fresh queue items; returns (fresh
    ``PrefetchItem``s, stale assignments).  MUST be called with
    ``state.lock`` held.  Stale items (older than the K-observation
    staleness bound) are skimmed off and returned for lie retirement —
    they are never served.  Fresh items keep their ``sparse`` flag so
    the mint step can attribute the served suggestion to the exact or
    approximate posterior (the SPARSE_MAX quality counters)."""
    fresh: List[PrefetchItem] = []
    stale: List[Dict[str, Any]] = []
    sparse_served = 0
    while state.queue and len(fresh) < want:
        # LIFO: always serve the *freshest* speculation — it was computed
        # against the most observations.  Older entries age toward the
        # staleness bound at the front and are swept by the pump.
        item = state.queue.pop()
        if state.observed - item.born_obs >= state.staleness:
            stale.append(item.assignment)
        else:
            fresh.append(item)
            sparse_served += bool(item.sparse)
    if stale:
        state.stats["invalidated"] += len(stale)
    if fresh:
        state.stats["hits"] += len(fresh)
    if sparse_served:
        # how much of the served traffic rode the approximate posterior —
        # the signal for tuning SPARSE_MAX (ROADMAP: sparse quality)
        state.stats["sparse_served"] = (
            state.stats.get("sparse_served", 0) + sparse_served)
    return fresh, stale


def retire_queue(state, terminal_only: bool = False) -> int:
    """Flush the prefetch queue and retire its constant-liar lies.  MUST
    be called with ``state.opt_lock`` held.  With ``terminal_only`` the
    flush only happens once the experiment can't serve again (stopped or
    budget spent) — the shared hygiene used by the pump's wind-down,
    ``status()`` and ``stop()``.  Returns the number retired."""
    with state.lock:
        if terminal_only and not (state.stopped
                                  or state.observed >= state.cfg.budget):
            return 0
        doomed = [i.assignment for i in state.queue]
        state.queue = []
        if doomed:
            state.stats["invalidated"] += len(doomed)
    for a in doomed:
        state.optimizer.forget(a)
    return len(doomed)


def serve_misses(state, make_suggestion: Callable[[Dict[str, Any]], Any]) -> int:
    """Serve parked `MissSlot`s with ONE batched ``ask`` (cross-scheduler
    request coalescing: concurrent queue misses share one model pass, not
    N serialized ones).  MUST be called with ``state.opt_lock`` held.
    ``make_suggestion`` mints a pending Suggestion from an assignment —
    called under ``state.lock``.  A round serves up to ``ASK_CHUNK``
    suggestions (the first slot is always taken whole); overflow slots
    stay parked for the next lock winner — usually their own waiting
    thread's retry loop.  Returns the number of slots served."""
    drain_ops(state)
    with state.lock:
        waiting = [s for s in state.miss_slots if not s.done]
        slots, acc = [], 0
        for s in waiting:
            if slots and acc + s.need > ASK_CHUNK:
                break
            slots.append(s)
            acc += s.need
        state.miss_slots = waiting[len(slots):]
        if not slots:
            return 0
        if state.stopped:
            total = 0
        else:
            headroom = (state.cfg.budget - state.observed
                        - len(state.pending))
            total = min(sum(s.need for s in slots), max(0, headroom))
    assigns = state.optimizer.ask(total) if total > 0 else []
    with state.lock:
        # headroom may have shrunk while we computed (queue pops register
        # pending under state.lock only) — never overdraw the budget
        headroom = state.cfg.budget - state.observed - len(state.pending)
        if state.stopped:
            headroom = 0
        usable = assigns[:max(0, headroom)]
        extra = assigns[len(usable):]
        i = 0
        for slot in slots:
            take = usable[i:i + slot.need]
            i += len(take)
            slot.result = [make_suggestion(a) for a in take]
            slot.done = True
            slot.event.set()
        extra.extend(usable[i:])
        if len(slots) > 1:
            state.stats["coalesced"] += len(slots) - 1
        state.stats["misses"] += len(slots)
    for a in extra:     # opt_lock still held
        state.optimizer.forget(a)
    return len(slots)


class SuggestionPump:
    """Per-experiment background worker: folds deferred observations,
    refits the model, prewarms compile buckets, invalidates stale queue
    entries, and keeps the prefetch queue at ``depth``.  Owns no locks of
    its own — it speaks the same ``opt_lock``/``state.lock`` protocol as
    the request path, always acquiring ``opt_lock`` with a timeout so
    ``stop()`` stays responsive even mid-fit."""

    #: fallback poll period — wakes are event-driven (observe/suggest/stop)
    IDLE_WAIT = 0.25

    def __init__(self, state, exp_id: str, depth: int,
                 make_suggestion: Callable[[Dict[str, Any]], Any]):
        self.state = state
        self.exp_id = exp_id
        self.depth = depth
        self.make_suggestion = make_suggestion
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._prewarm_goal = 0
        # miss counter at the last tick — the saturation signal.  Seeded
        # from the state so a restarted pump (close/resume reuses the
        # _ExperimentState) doesn't read pre-restart misses as live
        # saturation and serve sparse refills on an idle service.
        self._seen_misses = state.stats.get("misses", 0)
        self._thread = threading.Thread(
            target=self._run, name=f"suggest-pump-{exp_id}", daemon=True)

    @property
    def fit_key(self) -> tuple:
        """This experiment's coalescing key on the shared FitExecutor."""
        return ("fit", id(self.state))

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "SuggestionPump":
        self._thread.start()
        return self

    def wake(self) -> None:
        self._wake.set()

    def stop(self, join: bool = True, timeout: float = 10.0) -> None:
        self._stop.set()
        self._wake.set()
        cancel_fit(self.fit_key)
        if join and self._thread.is_alive() \
                and self._thread is not threading.current_thread():
            self._thread.join(timeout)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive() and not self._stop.is_set()

    # ------------------------------------------------------------ internals
    def _run(self) -> None:
        state = self.state
        # pipeline mode: ask() folds new data by cheap recondition; the
        # hyperparameter refits run here, in maintain(), when quiet
        state.optimizer.defer_fits = True
        try:
            self._prewarm()
            while not self._stop.is_set():
                busy = self._tick()
                if self._stop.is_set() or self._finished():
                    break
                if not busy:
                    self._wake.wait(self.IDLE_WAIT)
                    self._wake.clear()
        except Exception as e:  # noqa: pump death must not kill the service
            with state.lock:
                state.stats["pump_error"] = f"{type(e).__name__}: {e}"
        finally:
            # back to synchronous semantics for any pump-less aftermath
            state.optimizer.defer_fits = False

    def _finished(self) -> bool:
        state = self.state
        with state.lock:
            return state.stopped or state.observed >= state.cfg.budget

    def _prewarm(self) -> None:
        """Compile the shape buckets the near-term asks will need.  Reads
        only immutable optimizer config + jit caches — runs without
        ``opt_lock`` so the first suggests aren't blocked behind compiles."""
        state = self.state
        with state.lock:
            n = (state.observed + len(state.pending) + len(state.queue)
                 + self.depth + 8)
            goal = min(max(n, 1), state.cfg.budget + self.depth)
        if goal <= self._prewarm_goal:
            return
        self._prewarm_goal = goal
        warmed = state.optimizer.prewarm(goal, batch=min(self.depth, 8))
        if warmed:
            with state.lock:
                state.stats["prewarmed"] += warmed

    def _tick(self) -> bool:
        """One unit of pump work; returns True when anything was done (the
        loop re-ticks immediately) and False to idle-wait.  Hyperfits are
        NOT run here: debt is submitted to the shared ``FitExecutor`` so
        the pump thread only reconditions and pops."""
        state = self.state
        self._prewarm()     # cheap no-op once the goal bucket is compiled
        if not state.opt_lock.acquire(timeout=0.1):
            return True     # contended: re-check stop flag, then retry
        try:
            if self._stop.is_set():
                return False
            busy = drain_ops(state) > 0
            # a parked miss means the queue is already dry — serve it first
            busy = serve_misses(state, self.make_suggestion) > 0 or busy
            # terminal: nothing more will be served — retire the whole
            # queue's lies and let the thread wind down
            retired = retire_queue(state, terminal_only=True)
            # prune stale speculation, then top the queue back up
            with state.lock:
                stale = [i.assignment for i in state.queue
                         if state.observed - i.born_obs >= state.staleness]
                if stale:
                    state.queue = [
                        i for i in state.queue
                        if state.observed - i.born_obs < state.staleness]
                    state.stats["invalidated"] += len(stale)
                if state.stopped or state.observed >= state.cfg.budget:
                    want = 0
                else:
                    headroom = (state.cfg.budget - state.observed
                                - len(state.pending) - len(state.queue))
                    # chunked refill: bounded lock hold + bounded q-EI
                    # scan shapes; the loop re-ticks until at depth
                    want = min(self.depth - len(state.queue),
                               max(0, headroom), ASK_CHUNK)
                # saturation signal: requests outran the warm queue since
                # the last tick (served misses, or slots parked right now)
                misses_now = state.stats["misses"]
                saturated = (misses_now > self._seen_misses
                             or bool(state.miss_slots))
                self._seen_misses = misses_now
            for a in stale:
                state.optimizer.forget(a)
            swept = bool(stale) or retired > 0
            self._push_fit_debt(saturated, want)
            if want <= 0:
                return busy or swept
            # under saturation a speculative_ask optimizer refills from
            # its sparse posterior — bounded cost regardless of history
            # size, so the queue keeps pace past refit-bound throughput;
            # misses and synchronous asks still use the exact path.
            # sparse_eligible() confirms the sparse path would really
            # engage (enough history, fitted model), so the sparse_*
            # counters never mislabel exact suggestions
            spec = (saturated
                    and getattr(state.optimizer, "speculative_ask", False)
                    and state.optimizer.sparse_eligible())
            assigns = (state.optimizer.ask(want, speculative=True)
                       if spec else state.optimizer.ask(want))
            with state.lock:
                if state.stopped or state.observed >= state.cfg.budget:
                    take = []
                else:
                    headroom = (state.cfg.budget - state.observed
                                - len(state.pending) - len(state.queue))
                    take = assigns[:max(0, headroom)]
                state.queue.extend(
                    PrefetchItem(a, state.observed, sparse=spec)
                    for a in take)
                state.stats["prefilled"] += len(take)
                if spec:
                    state.stats["sparse_prefilled"] = (
                        state.stats.get("sparse_prefilled", 0) + len(take))
                extra = assigns[len(take):]
            for a in extra:
                state.optimizer.forget(a)
            return True
        finally:
            state.opt_lock.release()

    def _push_fit_debt(self, saturated: bool, want: int) -> None:
        """Submit owed hyperfit work to the shared executor, prioritized
        by how starved this experiment is.  Called with ``opt_lock``
        held (``maintenance_due`` reads optimizer state)."""
        if not self.state.optimizer.maintenance_due():
            return
        prio = (PRIO_MISS if saturated
                else PRIO_REFILL if want > 0 else PRIO_IDLE)
        fit_executor().submit(self.fit_key, self._maintain_job, prio)

    def _maintain_job(self) -> bool:
        """One deferred hyperfit, run on the shared FitExecutor.  Phase
        1 snapshots the fit under ``opt_lock`` (cheap), phase 2 runs the
        Adam loop with NO lock held, phase 3 installs the result under
        ``opt_lock`` (cheap) — requests never wait behind the fit
        itself.  Returns True to be requeued after losing the lock
        race."""
        state = self.state
        if self._stop.is_set():
            return False
        if not state.opt_lock.acquire(timeout=0.05):
            return not self._stop.is_set()
        try:
            drain_ops(state)            # the fit should see every fold
            job = state.optimizer.fit_job()
        finally:
            state.opt_lock.release()
        if job is None:
            return False
        install = job()                 # the expensive part — lock-free
        with state.opt_lock:
            if not self._stop.is_set():
                install()
                with state.lock:
                    state.stats["maintained"] = (
                        state.stats.get("maintained", 0) + 1)
        return False
