"""Service-side asynchronous suggestion pipeline (prefetch pump + miss
coalescing) — the machinery that makes ``LocalClient.suggest`` latency
independent of model cost.

Three cooperating pieces (all operating on one ``_ExperimentState``):

* **Prefetch pump** (`SuggestionPump`): a per-experiment background thread
  that keeps a bounded queue of speculative suggestions warm.  Each queued
  suggestion was produced by a real ``ask()`` (so it carries its
  constant-liar ``__lie`` token and EI already accounts for it); the pump
  also absorbs the *deferred optimizer work* — observation folds,
  hyperparameter refits, lie retirement — that ``observe``/``release``
  only enqueue.  Cold-start XLA compile cost is moved off-path too: the
  pump prewarms the power-of-two GP shape buckets at start and again
  before the history crosses into the next bucket.

* **Miss coalescing** (`serve_misses`): concurrent ``suggest`` calls that
  find the queue dry park a `MissSlot` and race for the optimizer lock;
  the winner serves *every* parked slot with a single batched ``ask(n)``
  instead of N serialized model fits.  Losers wait on their slot's event
  — they never touch the optimizer.

* **Staleness bound**: every queued suggestion remembers the observation
  count it was computed at (``born_obs``).  Once ``staleness`` (K) new
  observations have arrived, the suggestion is *invalidated* — dropped at
  pop time (and proactively by the pump), its constant-liar lie retired —
  so a warm queue can never serve a point the model has since learned to
  avoid.

Locking protocol (shared with ``repro.api.local``): ``state.opt_lock``
serializes all optimizer access (ask/tell/forget/restore) and must be
acquired *before* ``state.lock`` (cheap bookkeeping) when both are held.
``state.ops`` — the deferred tell/forget queue — is only ever popped
while holding ``opt_lock`` (see ``drain_ops``), which is what makes
create/resume's "drain then replay the log tail" sequence race-free.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List

#: Largest ``ask`` the pipeline issues per optimizer-lock hold (pump
#: refill ticks and coalesced miss rounds alike).  Bounds lock latency
#: (a request arriving mid-batch waits one chunk, not one queue fill)
#: and pins the q-EI scan shapes to the power-of-two pads <= 8 — exactly
#: what ``prewarm`` compiles, so no batch size ever pays a first-touch
#: scan compile on the request path.  Coalesced misses beyond a chunk
#: stay parked and are served by the next lock winner in ~one cheap
#: recondition+scan round each (hyperfits are deferred to the pump).
#: Only a single ``suggest(count > 8)`` call exceeds the chunk.
ASK_CHUNK = 8


class PrefetchItem:
    """One speculative suggestion waiting in the pump queue."""
    __slots__ = ("assignment", "born_obs")

    def __init__(self, assignment: Dict[str, Any], born_obs: int):
        self.assignment = assignment
        self.born_obs = born_obs


class MissSlot:
    """A ``suggest`` call waiting out a queue miss.  Filled (with up to
    ``need`` suggestions — possibly fewer, budget permitting) by whichever
    thread wins the optimizer lock and serves the coalesced batch."""
    __slots__ = ("need", "event", "result", "done")

    def __init__(self, need: int):
        self.need = need
        self.event = threading.Event()
        self.result: List[Any] = []
        self.done = False


def drain_ops(state) -> int:
    """Apply the deferred optimizer operations (observation folds and lie
    retirements that ``observe``/``release`` enqueued).  MUST be called
    with ``state.opt_lock`` held; pops under ``state.lock`` so no op is
    ever in flight outside both locks.  Returns the number applied."""
    with state.lock:
        ops, state.ops = state.ops, []
    if not ops:
        return 0
    tells: List[Any] = []
    for kind, payload in ops:
        if kind == "tell":
            tells.append(payload)
        else:                           # "forget"
            if tells:
                state.optimizer.tell(tells)
                tells = []
            state.optimizer.forget(payload)
    if tells:
        state.optimizer.tell(tells)
    return len(ops)


def pop_prefetched(state, want: int):
    """Pop up to ``want`` fresh queue items; returns (assignments, stale
    assignments).  MUST be called with ``state.lock`` held.  Stale items
    (older than the K-observation staleness bound) are skimmed off and
    returned for lie retirement — they are never served."""
    fresh: List[Dict[str, Any]] = []
    stale: List[Dict[str, Any]] = []
    while state.queue and len(fresh) < want:
        # LIFO: always serve the *freshest* speculation — it was computed
        # against the most observations.  Older entries age toward the
        # staleness bound at the front and are swept by the pump.
        item = state.queue.pop()
        if state.observed - item.born_obs >= state.staleness:
            stale.append(item.assignment)
        else:
            fresh.append(item.assignment)
    if stale:
        state.stats["invalidated"] += len(stale)
    if fresh:
        state.stats["hits"] += len(fresh)
    return fresh, stale


def retire_queue(state, terminal_only: bool = False) -> int:
    """Flush the prefetch queue and retire its constant-liar lies.  MUST
    be called with ``state.opt_lock`` held.  With ``terminal_only`` the
    flush only happens once the experiment can't serve again (stopped or
    budget spent) — the shared hygiene used by the pump's wind-down,
    ``status()`` and ``stop()``.  Returns the number retired."""
    with state.lock:
        if terminal_only and not (state.stopped
                                  or state.observed >= state.cfg.budget):
            return 0
        doomed = [i.assignment for i in state.queue]
        state.queue = []
        if doomed:
            state.stats["invalidated"] += len(doomed)
    for a in doomed:
        state.optimizer.forget(a)
    return len(doomed)


def serve_misses(state, make_suggestion: Callable[[Dict[str, Any]], Any]) -> int:
    """Serve parked `MissSlot`s with ONE batched ``ask`` (cross-scheduler
    request coalescing: concurrent queue misses share one model pass, not
    N serialized ones).  MUST be called with ``state.opt_lock`` held.
    ``make_suggestion`` mints a pending Suggestion from an assignment —
    called under ``state.lock``.  A round serves up to ``ASK_CHUNK``
    suggestions (the first slot is always taken whole); overflow slots
    stay parked for the next lock winner — usually their own waiting
    thread's retry loop.  Returns the number of slots served."""
    drain_ops(state)
    with state.lock:
        waiting = [s for s in state.miss_slots if not s.done]
        slots, acc = [], 0
        for s in waiting:
            if slots and acc + s.need > ASK_CHUNK:
                break
            slots.append(s)
            acc += s.need
        state.miss_slots = waiting[len(slots):]
        if not slots:
            return 0
        if state.stopped:
            total = 0
        else:
            headroom = (state.cfg.budget - state.observed
                        - len(state.pending))
            total = min(sum(s.need for s in slots), max(0, headroom))
    assigns = state.optimizer.ask(total) if total > 0 else []
    with state.lock:
        # headroom may have shrunk while we computed (queue pops register
        # pending under state.lock only) — never overdraw the budget
        headroom = state.cfg.budget - state.observed - len(state.pending)
        if state.stopped:
            headroom = 0
        usable = assigns[:max(0, headroom)]
        extra = assigns[len(usable):]
        i = 0
        for slot in slots:
            take = usable[i:i + slot.need]
            i += len(take)
            slot.result = [make_suggestion(a) for a in take]
            slot.done = True
            slot.event.set()
        extra.extend(usable[i:])
        if len(slots) > 1:
            state.stats["coalesced"] += len(slots) - 1
        state.stats["misses"] += len(slots)
    for a in extra:     # opt_lock still held
        state.optimizer.forget(a)
    return len(slots)


class SuggestionPump:
    """Per-experiment background worker: folds deferred observations,
    refits the model, prewarms compile buckets, invalidates stale queue
    entries, and keeps the prefetch queue at ``depth``.  Owns no locks of
    its own — it speaks the same ``opt_lock``/``state.lock`` protocol as
    the request path, always acquiring ``opt_lock`` with a timeout so
    ``stop()`` stays responsive even mid-fit."""

    #: fallback poll period — wakes are event-driven (observe/suggest/stop)
    IDLE_WAIT = 0.25

    def __init__(self, state, exp_id: str, depth: int,
                 make_suggestion: Callable[[Dict[str, Any]], Any]):
        self.state = state
        self.exp_id = exp_id
        self.depth = depth
        self.make_suggestion = make_suggestion
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._prewarm_goal = 0
        self._thread = threading.Thread(
            target=self._run, name=f"suggest-pump-{exp_id}", daemon=True)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "SuggestionPump":
        self._thread.start()
        return self

    def wake(self) -> None:
        self._wake.set()

    def stop(self, join: bool = True, timeout: float = 10.0) -> None:
        self._stop.set()
        self._wake.set()
        if join and self._thread.is_alive() \
                and self._thread is not threading.current_thread():
            self._thread.join(timeout)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive() and not self._stop.is_set()

    # ------------------------------------------------------------ internals
    def _run(self) -> None:
        state = self.state
        # pipeline mode: ask() folds new data by cheap recondition; the
        # hyperparameter refits run here, in maintain(), when quiet
        state.optimizer.defer_fits = True
        try:
            self._prewarm()
            while not self._stop.is_set():
                busy = self._tick()
                if self._stop.is_set() or self._finished():
                    break
                if not busy:
                    self._wake.wait(self.IDLE_WAIT)
                    self._wake.clear()
        except Exception as e:  # noqa: pump death must not kill the service
            with state.lock:
                state.stats["pump_error"] = f"{type(e).__name__}: {e}"
        finally:
            # back to synchronous semantics for any pump-less aftermath
            state.optimizer.defer_fits = False

    def _finished(self) -> bool:
        state = self.state
        with state.lock:
            return state.stopped or state.observed >= state.cfg.budget

    def _prewarm(self) -> None:
        """Compile the shape buckets the near-term asks will need.  Reads
        only immutable optimizer config + jit caches — runs without
        ``opt_lock`` so the first suggests aren't blocked behind compiles."""
        state = self.state
        with state.lock:
            n = (state.observed + len(state.pending) + len(state.queue)
                 + self.depth + 8)
            goal = min(max(n, 1), state.cfg.budget + self.depth)
        if goal <= self._prewarm_goal:
            return
        self._prewarm_goal = goal
        warmed = state.optimizer.prewarm(goal, batch=min(self.depth, 8))
        if warmed:
            with state.lock:
                state.stats["prewarmed"] += warmed

    def _tick(self) -> bool:
        """One unit of pump work; returns True when anything was done (the
        loop re-ticks immediately) and False to idle-wait."""
        state = self.state
        self._prewarm()     # cheap no-op once the goal bucket is compiled
        if not state.opt_lock.acquire(timeout=0.1):
            return True     # contended: re-check stop flag, then retry
        try:
            if self._stop.is_set():
                return False
            busy = drain_ops(state) > 0
            # a parked miss means the queue is already dry — serve it first
            busy = serve_misses(state, self.make_suggestion) > 0 or busy
            # terminal: nothing more will be served — retire the whole
            # queue's lies and let the thread wind down
            retired = retire_queue(state, terminal_only=True)
            # prune stale speculation, then top the queue back up
            with state.lock:
                stale = [i.assignment for i in state.queue
                         if state.observed - i.born_obs >= state.staleness]
                if stale:
                    state.queue = [
                        i for i in state.queue
                        if state.observed - i.born_obs < state.staleness]
                    state.stats["invalidated"] += len(stale)
                if state.stopped or state.observed >= state.cfg.budget:
                    want = 0
                else:
                    headroom = (state.cfg.budget - state.observed
                                - len(state.pending) - len(state.queue))
                    # chunked refill: bounded lock hold + bounded q-EI
                    # scan shapes; the loop re-ticks until at depth
                    want = min(self.depth - len(state.queue),
                               max(0, headroom), ASK_CHUNK)
            for a in stale:
                state.optimizer.forget(a)
            swept = bool(stale) or retired > 0
            if want <= 0:
                # queue is at depth: the quiet moment to pay the owed
                # hyperparameter refit, off the request path
                with state.lock:
                    quiet = not state.miss_slots
                if quiet and state.optimizer.maintain():
                    with state.lock:
                        state.stats["maintained"] = (
                            state.stats.get("maintained", 0) + 1)
                    return True
                return busy or swept
            assigns = state.optimizer.ask(want)
            with state.lock:
                if state.stopped or state.observed >= state.cfg.budget:
                    take = []
                else:
                    headroom = (state.cfg.budget - state.observed
                                - len(state.pending) - len(state.queue))
                    take = assigns[:max(0, headroom)]
                state.queue.extend(
                    PrefetchItem(a, state.observed) for a in take)
                state.stats["prefilled"] += len(take)
                extra = assigns[len(take):]
            for a in extra:
                state.optimizer.forget(a)
            return True
        finally:
            state.opt_lock.release()
