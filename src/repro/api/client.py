"""``SuggestionClient`` — the transport-agnostic boundary between trial
execution (scheduler/workers) and the suggestion service (optimizer +
system-of-record store).

Everything above this line (``Scheduler``, ``Orchestrator``, worker loops)
talks only in protocol messages; everything below it (``LocalClient``
in-process, ``HTTPClient`` over the wire) is interchangeable.  This is the
paper's §3.5 split: the suggestion service owns optimizer state and the
observation log, workers just loop suggest -> evaluate -> observe.
"""
from __future__ import annotations

import abc
from typing import TYPE_CHECKING, Optional

from repro.api.protocol import (BestResponse, CreateExperiment,
                                CreateResponse, Decision, ObserveRequest,
                                ObserveResponse, ReportRequest,
                                StatusResponse, SuggestBatch)

if TYPE_CHECKING:   # keep this module import-light: no repro.core at runtime
    from repro.core.suggest.base import Observation


class SuggestionClient(abc.ABC):
    """v1 suggest/observe protocol.  All methods are thread-safe; any of
    them may raise :class:`repro.api.protocol.ApiError`."""

    @abc.abstractmethod
    def create_experiment(self, req: CreateExperiment) -> CreateResponse:
        """Create a new experiment, or resume the one named by
        ``req.exp_id`` (replaying its observation log into a fresh
        optimizer exactly once)."""

    @abc.abstractmethod
    def suggest(self, exp_id: str, count: int = 1) -> SuggestBatch:
        """Hand out up to ``count`` new pending suggestions.  Never
        exceeds ``budget - observations - pending``; never returns the
        same pending assignment twice."""

    @abc.abstractmethod
    def observe(self, req: ObserveRequest) -> ObserveResponse:
        """Report one suggestion's outcome.  First observe wins; later
        observes of the same suggestion_id come back ``duplicate=True``."""

    @abc.abstractmethod
    def report(self, req: ReportRequest) -> Decision:
        """Stream one intermediate (step, value) progress point.  The
        service persists it to the trial's metric log and answers with the
        experiment-wide early-stopping decision (continue/stop/pause) —
        ONE shared rung table for all workers of the experiment."""

    @abc.abstractmethod
    def release(self, exp_id: str, suggestion_id: str) -> bool:
        """Return an unevaluated pending suggestion to the budget."""

    def requeue(self, exp_id: str, suggestion_id: str,
                assignment: Optional[dict] = None) -> bool:
        """Park a pending suggestion for re-serving (dead-worker
        recovery): it keeps its id and constant-liar lie, and the next
        ``suggest`` hands it out exactly once.  With ``assignment`` this
        is the rebalance *transfer* form — install a previous owner's
        pending under its original id.  Backends without fleet support
        decline."""
        return False

    def drain(self, exp_id: str):
        """Quiesce one experiment ahead of an ownership handover and
        return its parked pending suggestions
        (:class:`repro.api.protocol.DrainResponse`).  Backends without
        fleet support decline."""
        from repro.api.protocol import DrainResponse
        return DrainResponse(drained=False)

    @abc.abstractmethod
    def status(self, exp_id: str) -> StatusResponse:
        ...

    @abc.abstractmethod
    def stop(self, exp_id: str, state: str = "stopped") -> StatusResponse:
        """Terminate the experiment and reclaim pending suggestions."""

    @abc.abstractmethod
    def best_response(self, exp_id: str) -> BestResponse:
        ...

    # ------------------------------------------------------- conveniences
    def best(self, exp_id: str) -> Optional["Observation"]:
        from repro.core.suggest.base import Observation
        resp = self.best_response(exp_id)
        return Observation.from_json(resp.best) if resp.best else None

    def close(self) -> None:
        """Release transport resources (no-op for in-process clients)."""
