"""End-to-end training driver.

Runs any assigned architecture (full or --reduced) with the deterministic
data pipeline, AdamW + warmup-cosine, microbatch gradient accumulation,
atomic async checkpoints, and automatic --resume.  On this CPU container it
drives reduced configs (examples/train_lm.py trains a ~100M model); on real
hardware the same driver jits under the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.registry import get_config
from repro.data import DataConfig, TokenPipeline
from repro.launch import steps as S
from repro.optim import AdamWConfig, linear_warmup_cosine


def make_accum_train_step(cfg, opt_cfg, schedule, accum: int):
    """Gradient accumulation over `accum` microbatches inside one jit."""
    model, base_step = S.make_train_step(cfg, opt_cfg, schedule)
    if accum <= 1:
        return model, base_step
    from repro.models import LM
    from repro.optim import adamw_update

    def train_step(state, batch):
        def loss_fn(p, mb):
            return model.loss(S.cast_params(p, cfg.compute_dtype), mb)

        def micro(carry, mb):
            gsum, lsum = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], mb)
            return (jax.tree.map(jnp.add, gsum, g), lsum + loss), None

        micro_batches = jax.tree.map(
            lambda a: a.reshape((accum, a.shape[0] // accum) + a.shape[1:]),
            batch)
        zeros = jax.tree.map(
            lambda a: jnp.zeros(a.shape, jnp.float32), state["params"])
        (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), micro_batches)
        grads = jax.tree.map(lambda g: g / accum, gsum)
        lr = schedule(state["opt"]["step"]) if schedule else opt_cfg.lr
        new_p, new_opt, om = adamw_update(grads, state["opt"],
                                          state["params"], opt_cfg, lr)
        return ({"params": new_p, "opt": new_opt},
                {"loss": lsum / accum, "lr": lr, **om})

    return model, train_step


def train(arch: str, steps: int, batch: int, seq: int, *, reduced=True,
          lr=3e-4, warmup=20, accum=1, ckpt_dir: Optional[str] = None,
          ckpt_every=50, resume=False, seed=0, log_every=10,
          log=print) -> float:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    opt_cfg = AdamWConfig(lr=lr)
    schedule = linear_warmup_cosine(lr, warmup, steps)
    model, step_fn = make_accum_train_step(cfg, opt_cfg, schedule, accum)
    step_fn = jax.jit(step_fn, donate_argnums=0)

    state = S.init_train_state(cfg, jax.random.key(seed))
    start = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if resume and mgr and mgr.latest_step() is not None:
        state, meta = mgr.restore(state)
        start = int(meta["step"]) + 1
        log(f"[train] resumed from step {start - 1}")

    pipe = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
        seed=seed)).start_prefetch(from_step=start)
    last_loss = float("nan")
    t0 = time.time()
    try:
        for t in range(start, steps):
            _, np_batch = pipe.next_prefetched()
            batch_j = jax.tree.map(jnp.asarray, np_batch)
            state, metrics = step_fn(state, batch_j)
            if t % log_every == 0 or t == steps - 1:
                last_loss = float(metrics["loss"])
                rate = (t - start + 1) / (time.time() - t0)
                log(f"[train] step={t} loss={last_loss:.4f} "
                    f"lr={float(metrics['lr']):.2e} "
                    f"gnorm={float(metrics['grad_norm']):.2f} "
                    f"({rate:.2f} it/s)")
                if not np.isfinite(last_loss):
                    raise FloatingPointError(f"loss diverged at step {t}")
            if mgr and ckpt_every and t and t % ckpt_every == 0:
                mgr.save(t, state)
        last_loss = float(metrics["loss"])
    finally:
        pipe.stop_prefetch()
        if mgr:
            mgr.save(steps - 1, state)
            mgr.wait()
    return last_loss


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    loss = train(args.arch, args.steps, args.batch, args.seq,
                 reduced=args.reduced, lr=args.lr, accum=args.accum,
                 ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                 resume=args.resume, seed=args.seed)
    print(f"final loss: {loss:.4f}")


if __name__ == "__main__":
    main()
