import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against 512 placeholder host devices, prove the sharding config is
coherent (memory fits, collectives legal), and emit the roofline terms.

The two lines above MUST run before any jax import — jax locks the device
count on first init.  Never set this flag globally: smoke tests and benches
must see 1 device.

Usage:
  python -m repro.launch.dryrun --arch phi3-medium-14b --shape train_4k
  python -m repro.launch.dryrun --all            # every cell, both meshes
  python -m repro.launch.dryrun --all --mesh pod # every cell, single mesh

Artifacts: one JSON per cell under results/dryrun/ — EXPERIMENTS.md tables
are generated from these.
"""
import argparse
import gc
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs.registry import (cache_specs, get_config, input_specs,
                                    list_archs)
from repro.distributed.act_sharding import activation_sharding
from repro.distributed.auto_shard import sharded_bytes
from repro.distributed.hlo import analyze
from repro.distributed.roofline import roofline_terms
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as S
from repro.models.common import SHAPES, shape_applicable
from repro.optim import AdamWConfig


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes", "peak_memory_in_bytes")
    return {k: getattr(mem, k) for k in keys if hasattr(mem, k)}


def apply_opts(cfg, opts):
    """Hillclimb knobs: comma list like 'remat=none,scan=off'."""
    import dataclasses
    over = {}
    for item in (opts or "").split(","):
        if not item:
            continue
        k, _, v = item.partition("=")
        if k == "remat":
            over["remat"] = v
        elif k == "scan":
            over["scan_layers"] = v not in ("off", "0", "false")
        elif k == "dtype":
            over["dtype"] = v
        elif k == "capacity":
            over["capacity_factor"] = float(v)
        else:
            raise ValueError(f"unknown opt {k}")
    return dataclasses.replace(cfg, **over) if over else cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
             opts: str = "", verbose: bool = True) -> dict:
    cfg = apply_opts(get_config(arch), opts)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}" + (f"__{opts}" if opts else "")
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "opts": opts, "ok": False}

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(skipped=True, skip_reason=reason, ok=True)
        _write(out_dir, tag, rec)
        if verbose:
            print(f"[dryrun] {tag}: SKIP ({reason})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    try:
        n_params = cfg.param_count()
        n_active = cfg.active_param_count()
        specs_in = input_specs(cfg, shape)
        arg_bytes = 0
        with mesh:
            if shape.kind == "train":
                st_shapes = S.train_state_shapes(cfg)
                st_specs = S.state_specs(cfg, mesh, st_shapes)
                model, step = S.make_train_step(
                    cfg, AdamWConfig(), grad_specs=st_specs["params"])
                b_specs = S.batch_specs(cfg, shape, mesh, specs_in)
                jitted = jax.jit(
                    step,
                    in_shardings=(S.named(mesh, st_specs),
                                  S.named(mesh, b_specs)),
                    out_shardings=(S.named(mesh, st_specs), None),
                    donate_argnums=0)
                tok_sp = b_specs["tokens"]
                act = P(tok_sp[0], tok_sp[1])
                with activation_sharding(act):
                    lowered = jitted.lower(st_shapes, specs_in)
                arg_bytes = sharded_bytes(st_shapes, st_specs, mesh)
                tokens = shape.global_batch * shape.seq_len
                model_flops = 6.0 * n_params * tokens
                if cfg.moe:
                    model_flops = 6.0 * n_active * tokens
            elif shape.kind == "prefill":
                model, step = S.make_prefill_step(cfg, shape.seq_len)
                st_shapes = S.cast_param_shapes(
                    S.train_state_shapes(cfg)["params"], cfg.compute_dtype)
                p_specs = S.state_specs(cfg, mesh,
                                        {"params": st_shapes, "opt": None}
                                        )["params"]
                b_specs = S.batch_specs(cfg, shape, mesh, specs_in)
                jitted = jax.jit(
                    step,
                    in_shardings=(S.named(mesh, p_specs),
                                  S.named(mesh, b_specs)))
                tok_sp = b_specs["tokens"]
                act = P(tok_sp[0], tok_sp[1])
                with activation_sharding(act):
                    lowered = jitted.lower(st_shapes, specs_in)
                arg_bytes = sharded_bytes(st_shapes, p_specs, mesh)
                tokens = shape.global_batch * shape.seq_len
                model_flops = 2.0 * (n_active if cfg.moe else n_params) * tokens
            else:  # decode
                model, step = S.make_serve_step(cfg)
                st_shapes = S.cast_param_shapes(
                    S.train_state_shapes(cfg)["params"], cfg.compute_dtype)
                p_specs = S.state_specs(cfg, mesh,
                                        {"params": st_shapes, "opt": None}
                                        )["params"]
                cshapes, cspecs, tok_spec = S.decode_specs(cfg, shape, mesh)
                jitted = jax.jit(
                    step,
                    in_shardings=(S.named(mesh, p_specs),
                                  S.named(mesh, cspecs),
                                  S.named(mesh, tok_spec)),
                    out_shardings=(S.named(mesh, tok_spec),
                                   S.named(mesh, cspecs)),
                    donate_argnums=1)
                act = P(tok_spec[0] if len(tok_spec) else None, None)
                with activation_sharding(act):
                    lowered = jitted.lower(st_shapes, cshapes,
                                           specs_in["tokens"])
                arg_bytes = (sharded_bytes(st_shapes, p_specs, mesh)
                             + sharded_bytes(cshapes, cspecs, mesh))
                model_flops = 2.0 * (n_active if cfg.moe else n_params) \
                    * shape.global_batch
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0

        mem = _mem_dict(compiled.memory_analysis())
        xla_cost = compiled.cost_analysis() or {}
        if isinstance(xla_cost, (list, tuple)):    # newer jax: per-program list
            xla_cost = xla_cost[0] if xla_cost else {}
        xla_small = {k: v for k, v in xla_cost.items()
                     if k in ("flops", "bytes accessed", "transcendentals")}
        # trip-count-aware per-chip analysis (XLA's own cost_analysis counts
        # while bodies once; see distributed/hlo.py)
        hlo_text = compiled.as_text()
        _dump_hlo(out_dir, tag, hlo_text)
        hlo = analyze(hlo_text, n_dev)
        terms = roofline_terms(
            hlo, hlo["ici_bytes"],
            model_flops_per_chip=model_flops / n_dev)
        rec.update(
            ok=True, n_devices=n_dev, params=n_params, active_params=n_active,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            arg_bytes_per_device=arg_bytes,
            memory=mem, xla_cost_while_once=xla_small,
            collectives={"counts": hlo["collective_counts"],
                         "ici_bytes": hlo["collective_bytes"],
                         "total_ici_bytes": hlo["ici_bytes"]},
            roofline=terms)
        if verbose:
            print(f"[dryrun] {tag}: OK compile={t_compile:.0f}s "
                  f"dominant={terms['dominant']} "
                  f"frac={terms.get('roofline_fraction', 0):.3f} "
                  f"args/dev={arg_bytes/2**30:.2f}GiB")
            print("  memory_analysis:", mem)
            print("  cost_analysis(xla, while-once):", xla_small)
            print("  hlo_analysis(per-chip):",
                  {k: hlo[k] for k in ('flops', 'bytes accessed',
                                       'ici_bytes')})
    except Exception as e:  # a failure here is a bug in the system
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc())
        if verbose:
            print(f"[dryrun] {tag}: FAIL {type(e).__name__}: {e}")
    _write(out_dir, tag, rec)
    gc.collect()
    return rec


def _dump_hlo(out_dir: pathlib.Path, tag: str, text: str):
    """Gzipped compiled HLO per cell: lets analyzer improvements re-score
    the whole table without recompiling (see scripts/reanalyze.py)."""
    import gzip
    hdir = out_dir.parent / "hlo"
    hdir.mkdir(parents=True, exist_ok=True)
    with gzip.open(hdir / f"{tag}.txt.gz", "wt") as f:
        f.write(text)


def _write(out_dir: pathlib.Path, tag: str, rec: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=("pod", "multipod",
                                                       "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--opts", default="", help="hillclimb overrides")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)
    out = pathlib.Path(args.out)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                tag = f"{arch}__{shape}__{mesh_name}" + (
                    f"__{args.opts}" if args.opts else "")
                if args.skip_existing and (out / f"{tag}.json").exists():
                    prev = json.loads((out / f"{tag}.json").read_text())
                    if prev.get("ok"):
                        print(f"[dryrun] {tag}: cached OK")
                        continue
                rec = run_cell(arch, shape, mp, out, args.opts)
                n_fail += 0 if rec.get("ok") else 1
    print(f"[dryrun] done, failures={n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
