"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --reduced \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.launch import steps as S
from repro.models import LM


def serve(arch: str, batch: int, prompt_len: int, gen: int, *,
          reduced=True, seed=0, log=print):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = LM(cfg)
    params = S.cast_params(model.init(jax.random.key(seed)),
                           cfg.compute_dtype)

    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)
    pbatch = {"tokens": prompts}
    if cfg.family == "vlm":
        pbatch["img_embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.n_img_tokens, cfg.d_model)),
            cfg.compute_dtype)
    elif cfg.family == "encdec":
        pbatch["frames"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.encoder_seq, cfg.d_model)),
            cfg.compute_dtype)

    cache_len = prompt_len + gen
    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len))
    _, serve_step = S.make_serve_step(cfg)
    serve_step = jax.jit(serve_step, donate_argnums=1)

    t0 = time.time()
    cache, logits = prefill(params, pbatch)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0
    out = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        tok, cache = serve_step(params, cache, tok)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    seqs = jnp.stack(out, axis=1)
    log(f"[serve] prefill {batch}x{prompt_len} in {t_prefill * 1e3:.1f}ms; "
        f"decoded {gen - 1} steps in {t_decode * 1e3:.1f}ms "
        f"({(gen - 1) * batch / max(t_decode, 1e-9):.1f} tok/s)")
    return np.asarray(seqs)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)
    seqs = serve(args.arch, args.batch, args.prompt_len, args.gen,
                 reduced=args.reduced)
    print(f"generated shape: {seqs.shape}")


if __name__ == "__main__":
    main()
