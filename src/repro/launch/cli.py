"""The CLI verbs (paper §3.1), model- and language-agnostic:

  repro cluster create -f cluster.yml
  repro run -f experiment.yml [--cluster NAME] [--service URL]
  repro status EXPERIMENT_ID
  repro logs [--follow] EXPERIMENT_ID
  repro delete EXPERIMENT_ID
  repro cluster destroy -n CLUSTER_NAME
  repro serve-api [--host H] [--port N]
  repro serve-fleet [--shards N] [--shard URL ...] [--port N]

`run` executes the experiment's entrypoint ("module:function") under the
scheduler; with --background it returns immediately (monitor with
status/logs), mirroring the paper's split-screen workflow (Fig. 4).

`serve-api` exposes this store's suggestion service over HTTP (the v1
suggest/observe protocol — endpoints, schemas, and error codes are
documented in API.md at the repo root).  A worker on another host then
drives the same experiment with `repro run -f exp.yml --service URL`:
suggestions and observations flow through the service, while trial logs
and checkpoints stay in the worker's local store.
"""
from __future__ import annotations

import argparse
import json
import signal
import sys
import threading
import time

import yaml

from repro.api.http import serve_api
from repro.core.experiment import ExperimentConfig
from repro.core.monitor import (format_cluster_status,
                                format_experiment_status)
from repro.core.orchestrator import Orchestrator


def _load(path: str):
    with open(path) as f:
        return yaml.safe_load(f)


def _install_graceful_shutdown(shutdown_fn, what: str) -> threading.Event:
    """SIGTERM/SIGINT -> graceful ``shutdown_fn()``.  The handler runs in
    the main thread, which is blocked inside ``serve_forever`` — calling
    ``httpd.shutdown()`` from there would deadlock, so the handler hands
    the work to a helper thread and lets ``serve_forever`` return."""
    fired = threading.Event()

    def handler(signum, frame):
        if fired.is_set():      # second signal: let the default kill us
            signal.signal(signum, signal.SIG_DFL)
            signal.raise_signal(signum)
            return
        fired.set()
        name = signal.Signals(signum).name
        print(f"\n{what}: {name} received, shutting down gracefully "
              f"(again to force)", file=sys.stderr)
        threading.Thread(target=shutdown_fn, name="graceful-shutdown",
                         daemon=True).start()

    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, handler)
    return fired


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro",
                                 description="Orchestrate-JAX CLI")
    ap.add_argument("--store", default=".orchestrate")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_cluster = sub.add_parser("cluster")
    csub = p_cluster.add_subparsers(dest="ccmd", required=True)
    c_create = csub.add_parser("create")
    c_create.add_argument("-f", "--file", required=True)
    c_destroy = csub.add_parser("destroy")
    c_destroy.add_argument("-n", "--name", required=True)
    c_status = csub.add_parser("status")
    c_status.add_argument("-n", "--name", required=True)

    p_run = sub.add_parser("run")
    p_run.add_argument("-f", "--file", required=True)
    p_run.add_argument("--cluster", default=None)
    p_run.add_argument("--background", action="store_true")
    p_run.add_argument("--service", default=None, metavar="URL",
                       help="drive a remote suggestion service "
                            "(repro serve-api) instead of in-process")
    p_run.add_argument("--fleet", default=None, metavar="URL",
                       help="drive a sharded fleet through its manager "
                            "(repro serve-fleet, API.md §Fleet)")
    p_run.add_argument("--resume", default=None, metavar="EXPERIMENT_ID",
                       help="resume an existing experiment id")

    p_serve = sub.add_parser(
        "serve-api", help="serve the v1 suggestion API over HTTP (API.md)")
    p_serve.add_argument("--host", default="127.0.0.1")
    p_serve.add_argument("--port", type=int, default=8765)

    p_fleet = sub.add_parser(
        "serve-fleet",
        help="serve a sharded fleet: manager + N shards (API.md §Fleet)")
    p_fleet.add_argument("--host", default="127.0.0.1")
    p_fleet.add_argument("--port", type=int, default=8766)
    p_fleet.add_argument("--shards", type=int, default=0, metavar="N",
                         help="spawn N in-process shards over this store")
    p_fleet.add_argument("--shard", action="append", default=[],
                         metavar="URL", dest="shard_urls",
                         help="attach an external repro serve-api shard "
                              "(repeatable)")
    p_fleet.add_argument("--period", type=float, default=1.0,
                         help="heartbeat period in seconds")
    p_fleet.add_argument("--standby", action="store_true",
                         help="start as a warm standby: watch the active "
                              "manager's lease in the shared store and "
                              "take over (with a bumped leadership term) "
                              "when it goes stale")

    p_status = sub.add_parser("status")
    p_status.add_argument("experiment_id")
    p_status.add_argument("--service", default=None, metavar="URL",
                          help="query a remote suggestion service instead "
                               "of the local store")
    p_status.add_argument("--fleet", default=None, metavar="URL",
                          help="query through a fleet manager "
                               "(routes to the owning shard)")

    p_logs = sub.add_parser("logs")
    p_logs.add_argument("experiment_id")
    p_logs.add_argument("--follow", action="store_true")

    p_delete = sub.add_parser("delete")
    p_delete.add_argument("experiment_id")

    p_list = sub.add_parser("list")

    args = ap.parse_args(argv)
    orch = Orchestrator(args.store)

    if args.cmd == "cluster":
        if args.ccmd == "create":
            cluster = orch.cluster_create(_load(args.file))
            print(f"cluster {cluster.name!r} created")
            print(format_cluster_status(cluster.status()))
        elif args.ccmd == "destroy":
            ok = orch.cluster_destroy(args.name)
            print(f"cluster {args.name!r} "
                  f"{'destroyed' if ok else 'not found'}")
            print("experiment records remain in the store")
            return 0 if ok else 1
        else:
            print(format_cluster_status(orch.cluster_status(args.name)))
        return 0

    if args.cmd == "serve-api":
        try:
            server = serve_api(orch.store, host=args.host, port=args.port)
        except OSError as e:
            print(f"cannot bind {args.host}:{args.port}: {e}",
                  file=sys.stderr)
            return 1
        # handler first: the "listening on" line is the readiness signal,
        # and a supervisor may SIGTERM the moment it sees it
        _install_graceful_shutdown(server.shutdown, "serve-api")
        print(f"suggestion service (protocol v1) listening on {server.url}")
        print(f"store: {orch.store.root}  —  see API.md for the endpoints")
        server.serve_forever()
        print("serve-api: shut down cleanly", file=sys.stderr)
        return 0

    if args.cmd == "serve-fleet":
        from repro.fleet import serve_fleet
        try:
            server = serve_fleet(orch.store, shards=args.shards,
                                 shard_urls=args.shard_urls,
                                 host=args.host, port=args.port,
                                 period=args.period,
                                 standby=args.standby)
        except (OSError, ValueError) as e:
            print(f"cannot start fleet: {e}", file=sys.stderr)
            return 1
        shards = server.manager.shard_map().shards
        _install_graceful_shutdown(server.shutdown, "serve-fleet")
        print(f"fleet manager (protocol v1) listening on {server.url}")
        for sid, url in sorted(shards.items()):
            print(f"  shard {sid}: {url}")
        print(f"store: {orch.store.root}  —  see API.md §Fleet")
        server.serve_forever()
        print("serve-fleet: shut down cleanly", file=sys.stderr)
        return 0

    if args.cmd == "run":
        from repro.api.protocol import ApiError
        cfg = ExperimentConfig.from_json(_load(args.file))
        try:
            exp_id = orch.run(cfg, cluster=args.cluster,
                              background=args.background,
                              exp_id=args.resume, service=args.service,
                              fleet=args.fleet)
        except ApiError as e:
            print(f"error: {e}", file=sys.stderr)
            return 1
        print(f"experiment {exp_id} "
              f"{'started' if args.background else 'complete'}")
        if not args.background:
            print(format_experiment_status(exp_id, orch.status(exp_id)))
        else:
            # foreground process keeps the background scheduler alive
            try:
                while orch.status(exp_id).get("state") == "running":
                    time.sleep(0.5)
            except KeyboardInterrupt:
                orch.delete(exp_id)
        return 0

    if args.cmd == "status":
        from repro.api.protocol import ApiError
        try:
            if args.fleet:
                from repro.fleet import FleetClient
                client = FleetClient(args.fleet, heartbeat=False)
                try:
                    st = client.status(args.experiment_id).to_json()
                finally:
                    client.close()
            elif args.service:
                from repro.api.http import HTTPClient
                st = HTTPClient(args.service).status(
                    args.experiment_id).to_json()
            else:
                st = orch.status(args.experiment_id)
        except ApiError as e:
            print(f"error: {e}", file=sys.stderr)
            return 1
        print(format_experiment_status(args.experiment_id, st))
        return 0

    if args.cmd == "logs":
        for line in orch.logs(args.experiment_id, follow=args.follow):
            print(line)
        return 0

    if args.cmd == "delete":
        orch.delete(args.experiment_id)
        print(f"experiment {args.experiment_id} deleted "
              f"(records remain in the store)")
        return 0

    if args.cmd == "list":
        for e in orch.store.list_experiments():
            st = orch.store.get_status(e)
            print(f"{e}  {st.get('state', '?'):10s} "
                  f"obs={st.get('observations', 0)}")
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
