"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod'
    axis (the multi-pod dry-run proves this axis shards)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (tests / population training)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    return jax.make_mesh(shape, axes)
