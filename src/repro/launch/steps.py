"""jit-able step functions + their sharding specs for a given (arch, shape,
mesh) cell.  Used by the dry-run, the real trainer, and the server.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import cache_specs, input_specs
from repro.distributed.auto_shard import (auto_spec, batch_seq_spec,
                                          tree_specs)
from repro.models import LM
from repro.models.common import ModelConfig, ShapeSpec
from repro.optim import AdamWConfig, adamw_init, adamw_update


# ==========================================================================
# step builders (pure functions of pytrees)
# ==========================================================================
def cast_params(params, dtype):
    """Cast float leaves (f32 masters) to the compute dtype.  Done ONCE per
    step outside the layer scan so FSDP all-gathers move bf16, not f32 —
    this halves parameter collective traffic."""
    return jax.tree.map(
        lambda a: a.astype(dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, params)


def cast_param_shapes(shapes, dtype):
    """ShapeDtypeStruct mirror of ``cast_params`` (serving loads weights
    pre-cast; the dry-run lowers against bf16 param specs)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype), shapes)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, schedule=None,
                    grad_specs=None):
    """grad_specs: optional PartitionSpec pytree matching the params.
    Anchoring gradients to the parameter sharding lets the SPMD partitioner
    emit per-layer reduce-scatters instead of full f32 all-reduces — 2x less
    gradient ICI traffic (§Perf iteration 2)."""
    model = LM(cfg)

    def train_step(state, batch):
        # differentiate w.r.t. the bf16-cast params (casts dedupe, FSDP
        # gathers move bf16); AdamW re-accumulates in f32.
        p_c = cast_params(state["params"], cfg.compute_dtype)

        def loss_fn(p):
            return model.loss(p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(p_c)
        if grad_specs is not None:
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_specs)
        lr = schedule(state["opt"]["step"]) if schedule else opt_cfg.lr
        new_p, new_opt, om = adamw_update(
            grads, state["opt"], state["params"], opt_cfg, lr)
        metrics = dict(metrics, loss=loss, lr=lr, **om)
        return {"params": new_p, "opt": new_opt}, metrics

    return model, train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    model = LM(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len)

    return model, prefill_step


def make_serve_step(cfg: ModelConfig):
    """One greedy decode step: (params, cache, tokens) -> (next, cache)."""
    model = LM(cfg)

    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return model, serve_step


def init_train_state(cfg: ModelConfig, rng) -> Dict[str, Any]:
    model = LM(cfg)
    params = model.init(rng)
    return {"params": params, "opt": adamw_init(params)}


def train_state_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_train_state(cfg, jax.random.key(0)))


# ==========================================================================
# sharding specs per cell
# ==========================================================================
def state_specs(cfg: ModelConfig, mesh: Mesh, state_shapes) -> Any:
    """Params + optimizer state: greedy auto-sharding (scan dims skipped)."""
    p_specs = tree_specs(state_shapes["params"], mesh)
    return {"params": p_specs,
            "opt": {"m": p_specs, "v": p_specs, "step": P()}}


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                specs: Dict[str, Any]) -> Dict[str, Any]:
    """Activation input shardings for train/prefill batches."""
    out = {}
    for name, s in specs.items():
        if name in ("tokens", "labels"):
            out[name] = batch_seq_spec(mesh, s.shape[0], s.shape[1])
        elif name in ("img_embeds", "frames"):
            bs = batch_seq_spec(mesh, s.shape[0], s.shape[1])
            out[name] = P(*bs, None)
        else:
            raise KeyError(name)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Any:
    """(cache_specs_tree, token_spec) shardings for serve_step."""
    cshapes = cache_specs(cfg, shape)

    def leaf_spec(x):
        return auto_spec(x.shape, mesh, skip_leading=True)

    cspecs = jax.tree.map(leaf_spec, cshapes)
    # 'pos' is (B,): shard over what divides, else replicate
    cspecs["pos"] = batch_seq_spec(mesh, shape.global_batch, None)
    tok = batch_seq_spec(mesh, shape.global_batch, None)
    return cshapes, cspecs, tok


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
